//! Job server: the simulator as a service.
//!
//! Line-delimited JSON over TCP.  Requests:
//!
//! ```json
//! {"cmd": "ping"}
//! {"cmd": "bench", "benchmark": "vector_addition", "profile": "small",
//!  "mode": "vector", "lanes": 2}
//! {"cmd": "sweep", "benchmarks": ["vector_addition"], "profiles": ["test"],
//!  "modes": ["vector"], "lanes": [1, 2, 4], "vlens": [128, 256],
//!  "elens": [32, 64], "timing": ["baseline", "burst-mem"]}
//! {"cmd": "batch", "requests": [{"cmd": "ping"}, {"cmd": "bench", ...}]}
//! {"cmd": "warm", "benchmarks": ["vector_addition"], "lanes": [1, 2]}
//! {"cmd": "describe", "what": "datapath"}
//! {"cmd": "list"}
//! {"cmd": "stats"}
//! {"cmd": "metrics"}
//! {"cmd": "shutdown"}
//! ```
//!
//! Responses are single-line JSON with `"ok": true/false`.
//!
//! **Execution model** (the high-throughput serving path): one poller
//! thread owns every accepted socket — non-blocking, readiness-polled
//! via the thin [`crate::util::poll`] `poll(2)` wrapper — so the
//! OS-thread count is independent of the connection count.  The poller
//! parses complete JSON lines and admits each request to one
//! process-wide bounded [`Executor`] pool, so N requests pipelined on
//! one connection execute *concurrently* across the pool.  When the
//! bounded queue is full the request is refused immediately with a
//! structured `{"ok": false, "busy": true}` error — backpressure, not
//! unbounded buffering.  Responses are routed back through capped
//! per-connection write queues the poller flushes on writability; a
//! client that stops reading gets the same structured `busy` once its
//! queue cap is hit ([`WRITE_QUEUE_CAP`]), never a stalled poller.
//! Responses to requests that carry an `"id"` field are queued the
//! moment they complete with the id echoed (out-of-order completion
//! allowed); responses to id-less requests are delivered strictly in
//! request order, byte-identical to the old serial server.
//!
//! **Autoscaling**: with an [`AutoscaleSpec`] (`arrow serve
//! --workers-min/--workers-max`) a control loop drains the queue-wait
//! histogram window every interval and resizes the executor pool —
//! growing on sustained queue-wait p90, shrinking towards the floor on
//! idle windows — and retargets the session pool alongside.  Every
//! resize is a trace instant plus a Prometheus counter, and the
//! current/target worker counts are gauges.
//!
//! Every evaluation (`bench`, `sweep`, and both inside `batch`) goes
//! through one process-wide [`Evaluator`] shared across all
//! connections, so assembled programs, pooled sealed sessions
//! (pre-warmable via `warm`) — and, when the server is started with a
//! cache directory, stored results — are reused across requests.
//!
//! **Observability**: per-command latency histograms (measured from
//! admission to completion, queue wait included) plus
//! queue-depth/served/rejected counters, surfaced by `{"cmd": "stats"}`
//! — answered on the connection thread, so stats stay readable even
//! when the pool is saturated.  `arrow loadgen` drives this endpoint.
//!
//! **Shutdown**: `{"cmd": "shutdown"}` (loopback peers only) or SIGTERM
//! stop accepting connections and drain queued + in-flight requests
//! before the serve loop returns, so fleet supervisors can stop workers
//! without killing them mid-request.
//!
//! Fleet integration: `sweep` responses carry `elapsed_ms` (measured
//! wall-time, closing the coordinator's shard-cost feedback loop), the
//! `shard` handshake advertises live `load` counters — now including
//! queue depth and rejected requests, so the coordinator's cost model
//! sees saturation — and a server started with a [`JoinSpec`] (`arrow
//! serve --join`) announces itself to a coordinator's registry via
//! [`crate::bench::fleet`] and keeps heartbeating for as long as it
//! lives.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{
    AtomicBool, AtomicU64, AtomicUsize, Ordering,
};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::{Duration, Instant};

use crate::bench::fleet;
use crate::bench::models::{workload_names, ModelId, MODELS};
use crate::bench::profiles::{self, TimingVariant};
use crate::bench::runner::Mode;
use crate::bench::store::ResultStore;
use crate::bench::suite::{Benchmark, BENCHMARKS};
use crate::bench::sweep::{self, SweepSpec};
use crate::bench::{EvalPoint, Evaluator, Profile, WorkloadKind};
use crate::util::histogram::Histogram;
use crate::util::json::{self, Json};
use crate::util::poll::{self, PollFd, Pollable, POLLIN, POLLOUT};
use crate::vector::ArrowConfig;

use super::describe;
use super::executor::{Executor, ExecutorOptions, Reject};

/// Upper bound on one request's sweep grid, to keep a single connection
/// from monopolising the process.  Public because the cluster
/// coordinator sizes its shards against this cap (and the `shard`
/// handshake advertises it).
pub const MAX_SWEEP_GRID: usize = 4096;

/// Upper bound on sub-requests in one `batch` envelope (advertised by
/// the `shard` handshake; the coordinator chunks against it).
pub const MAX_BATCH_REQUESTS: usize = 256;

/// Cap on one `sleep` request, so the load-test scaffold cannot park a
/// pool worker indefinitely.
pub const MAX_SLEEP_MS: u64 = 5_000;

/// How long a draining server waits for queued + in-flight requests
/// before giving up and exiting anyway.
pub const SHUTDOWN_GRACE: Duration = Duration::from_secs(20);

/// Poller readiness timeout: an idle tick re-checks the drain flags,
/// so shutdown/SIGTERM responsiveness matches the old accept loop.
const POLL_TICK: Duration = Duration::from_millis(25);

/// Cap on rendered-but-unwritten response bytes per connection.  A
/// client that pipelines requests without reading responses hits this
/// and gets structured `busy` answers instead of stalling the poller
/// (or growing the heap unboundedly).
pub const WRITE_QUEUE_CAP: usize = 256 * 1024;

/// Command kinds tracked by the per-command latency histograms.  The
/// last entry is the catch-all for unknown commands.
const KIND_NAMES: [&str; 11] = [
    "ping", "bench", "sweep", "batch", "describe", "list", "shard",
    "stats", "warm", "sleep", "other",
];

/// Histogram slot for a request's `cmd`.
fn kind_of(cmd: Option<&str>) -> usize {
    cmd.and_then(|c| KIND_NAMES.iter().position(|&k| k == c))
        .unwrap_or(KIND_NAMES.len() - 1)
}

/// Live load counters and latency histograms for one server process,
/// shared by every connection.  The `shard` handshake surfaces the
/// counters to coordinators, the `--join` announcer folds them into
/// each registration heartbeat (so a fleet coordinator sees worker
/// saturation without probing), and `{"cmd": "stats"}` reports the
/// whole thing including p50/p99/p999 per command.
#[derive(Debug, Default)]
pub struct ServerStats {
    /// Requests currently executing, across all connections.
    pub in_flight: AtomicUsize,
    /// Sweep requests (cluster shards) served since startup.
    pub sweeps_served: AtomicU64,
    /// Requests completed (any command, success or error response).
    pub served: AtomicU64,
    /// Requests refused by admission control (queue full / draining).
    pub rejected: AtomicU64,
    /// Executor queue depth, mirrored at each admission/completion.
    pub queue_depth: AtomicUsize,
    /// Sockets the poller currently owns (accepted connections).
    pub poller_fds: AtomicUsize,
    /// Rendered-but-unwritten response bytes across all connections,
    /// refreshed by the poller each tick.
    pub write_queue_bytes: AtomicUsize,
    /// Live executor worker count, mirrored by the poller/autoscaler.
    pub workers_current: AtomicUsize,
    /// Worker count the autoscaler is steering towards.
    pub workers_target: AtomicUsize,
    /// Aggregate latency across every command.
    latency_all: Histogram,
    /// Per-command latency, indexed by [`kind_of`].
    latency: [Histogram; KIND_NAMES.len()],
    /// Interval window: drained (snapshot-and-reset) by each `stats`
    /// request, so pollers see per-window latency instead of only
    /// since-startup aggregates.
    latency_window: Histogram,
    /// Queue-wait (admission → worker pickup) interval window, drained
    /// by the autoscaler each control tick: sustained high p90 here
    /// means the pool is undersized.
    queue_wait_window: Histogram,
}

impl ServerStats {
    /// Record one completed request: admission-to-completion latency
    /// (queue wait included) into the aggregate and per-command
    /// histograms, plus the served counter.
    pub fn record(&self, kind: usize, elapsed: Duration) {
        self.served.fetch_add(1, Ordering::Relaxed);
        self.latency_all.record(elapsed);
        self.latency_window.record(elapsed);
        self.latency[kind.min(KIND_NAMES.len() - 1)].record(elapsed);
    }

    /// Record one request's queue wait (admission → worker pickup)
    /// into the autoscaler's interval window.
    pub fn record_queue_wait(&self, waited: Duration) {
        self.queue_wait_window.record(waited);
    }

    /// Drain the queue-wait window (autoscaler control tick).
    pub fn drain_queue_wait_window(&self) -> Histogram {
        self.queue_wait_window.snapshot_reset()
    }

    /// The load object both the handshake and the registration payload
    /// carry.  `queue_depth`/`rejected` are the saturation signals the
    /// fleet coordinator's costing reads.
    pub fn load_json(&self) -> Json {
        Json::obj(vec![
            (
                "in_flight",
                (self.in_flight.load(Ordering::Relaxed) as u64).into(),
            ),
            (
                "sweeps_served",
                self.sweeps_served.load(Ordering::Relaxed).into(),
            ),
            (
                "queue_depth",
                (self.queue_depth.load(Ordering::Relaxed) as u64).into(),
            ),
            ("served", self.served.load(Ordering::Relaxed).into()),
            ("rejected", self.rejected.load(Ordering::Relaxed).into()),
        ])
    }

    /// The `latency_us` object of the `stats` response: the aggregate
    /// plus every command that has actually been seen.
    fn latency_json(&self) -> Json {
        let mut fields = vec![("all", self.latency_all.summary_json())];
        for (i, name) in KIND_NAMES.iter().enumerate() {
            if self.latency[i].count() > 0 {
                fields.push((name, self.latency[i].summary_json()));
            }
        }
        Json::obj(fields)
    }
}

/// Render the whole process as Prometheus text (the `{"cmd": "metrics"}`
/// body): the static [`metrics`](crate::obs::metrics) registry plus the
/// server's own live counters, gauges, and latency summaries.
fn metrics_text(evaluator: &Evaluator, stats: &ServerStats) -> String {
    use crate::obs::metrics;
    let mut out = String::new();
    metrics::render_registry(&mut out);
    metrics::render_counter(
        &mut out,
        "arrow_requests_served_total",
        "Requests completed (any command, success or error response)",
        stats.served.load(Ordering::Relaxed),
    );
    metrics::render_counter(
        &mut out,
        "arrow_requests_rejected_total",
        "Requests refused by admission control",
        stats.rejected.load(Ordering::Relaxed),
    );
    metrics::render_counter(
        &mut out,
        "arrow_sweeps_served_total",
        "Sweep requests (cluster shards) served",
        stats.sweeps_served.load(Ordering::Relaxed),
    );
    metrics::render_gauge(
        &mut out,
        "arrow_requests_in_flight",
        "Requests executing right now",
        stats.in_flight.load(Ordering::Relaxed) as u64,
    );
    metrics::render_gauge(
        &mut out,
        "arrow_executor_queue_depth",
        "Jobs waiting in the bounded executor queue",
        stats.queue_depth.load(Ordering::Relaxed) as u64,
    );
    metrics::render_gauge(
        &mut out,
        "arrow_poller_fds",
        "Accepted connections the poller currently owns",
        stats.poller_fds.load(Ordering::Relaxed) as u64,
    );
    metrics::render_gauge(
        &mut out,
        "arrow_conn_write_queue_bytes",
        "Rendered-but-unwritten response bytes across all connections",
        stats.write_queue_bytes.load(Ordering::Relaxed) as u64,
    );
    metrics::render_gauge(
        &mut out,
        "arrow_executor_workers",
        "Live executor worker threads",
        stats.workers_current.load(Ordering::Relaxed) as u64,
    );
    metrics::render_gauge(
        &mut out,
        "arrow_executor_workers_target",
        "Worker count the autoscaler is steering towards",
        stats.workers_target.load(Ordering::Relaxed) as u64,
    );
    metrics::render_gauge(
        &mut out,
        "arrow_session_pool_size",
        "Sealed sessions currently pooled",
        evaluator.sessions().len() as u64,
    );
    metrics::render_gauge(
        &mut out,
        "arrow_programs_cached",
        "Assembled programs in the shared program cache",
        evaluator.programs().len() as u64,
    );
    let mut typed = true;
    metrics::render_histogram(
        &mut out,
        "arrow_request_latency_us",
        "Request latency, admission to completion, microseconds",
        &[("kind", "all")],
        &stats.latency_all,
        typed,
    );
    typed = false;
    for (i, name) in KIND_NAMES.iter().enumerate() {
        if stats.latency[i].count() > 0 {
            metrics::render_histogram(
                &mut out,
                "arrow_request_latency_us",
                "",
                &[("kind", name)],
                &stats.latency[i],
                typed,
            );
        }
    }
    out
}

/// Balances `in_flight` by drop, so a panicking request handler cannot
/// permanently inflate the load every heartbeat reports — the executor
/// catches the panic, unwinding runs this guard's destructor, and the
/// gauge returns to truth.
struct InFlightGuard<'a>(&'a ServerStats);

impl<'a> InFlightGuard<'a> {
    fn new(stats: &'a ServerStats) -> InFlightGuard<'a> {
        stats.in_flight.fetch_add(1, Ordering::Relaxed);
        InFlightGuard(stats)
    }
}

impl Drop for InFlightGuard<'_> {
    fn drop(&mut self) {
        self.0.in_flight.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Fleet-membership side of a worker: where to announce ourselves and
/// how to be addressed (`arrow serve --join`).
#[derive(Debug, Clone)]
pub struct JoinSpec {
    /// Coordinator registry endpoint (`host:port` of `arrow sweep
    /// --listen`).
    pub coordinator: String,
    /// Address advertised for shard dispatch.  Defaults to the bound
    /// listen address — override when the worker sits behind NAT or
    /// binds a wildcard address coordinators cannot dial back.
    pub advertise: Option<String>,
    /// Re-registration (heartbeat) interval.
    pub interval: Duration,
}

impl JoinSpec {
    pub fn new(coordinator: impl Into<String>) -> JoinSpec {
        JoinSpec {
            coordinator: coordinator.into(),
            advertise: None,
            interval: fleet::HEARTBEAT_INTERVAL,
        }
    }
}

fn err_response(msg: impl Into<String>) -> Json {
    Json::obj(vec![("ok", false.into()), ("error", Json::Str(msg.into()))])
}

/// Handle one request object against a shared evaluator (pure;
/// exercised directly by tests).  Load counters read as zero — real
/// connections go through [`handle_request_with`].
pub fn handle_request(req: &Json, evaluator: &Evaluator) -> Json {
    handle_request_with(req, evaluator, &ServerStats::default())
}

/// [`handle_request`] with the process-wide load counters, so the
/// `shard` handshake can advertise them and sweep handling can count
/// shards served.
pub fn handle_request_with(
    req: &Json,
    evaluator: &Evaluator,
    stats: &ServerStats,
) -> Json {
    match req.get("cmd").and_then(Json::as_str) {
        Some("ping") => {
            Json::obj(vec![("ok", true.into()), ("pong", true.into())])
        }
        // Cluster handshake: who are you, what do you accept?  The
        // coordinator refuses to dispatch shards to a worker whose
        // crate version differs from its own — simulator timing (and
        // the result-store key space) may have changed between
        // versions, so mixed-version reports must never merge silently.
        Some("shard") => {
            let mut fields = vec![
                ("ok", true.into()),
                ("role", "worker".into()),
                ("version", env!("CARGO_PKG_VERSION").into()),
                ("max_grid", (MAX_SWEEP_GRID as u64).into()),
                ("max_batch", (MAX_BATCH_REQUESTS as u64).into()),
                ("store", evaluator.store().is_some().into()),
                // Live load, so a coordinator (or operator) sees how
                // busy this worker is straight from the handshake.
                ("load", stats.load_json()),
            ];
            // Ledger health rides the handshake, so a coordinator (or
            // an operator poking a worker) sees how bloated this
            // worker's persistent store is without filesystem access.
            if let Some(store) = evaluator.store() {
                let s = store.stats();
                fields.push((
                    "ledger",
                    Json::obj(vec![
                        ("entries", (s.entries as u64).into()),
                        ("bytes", s.bytes.into()),
                        ("superseded", s.superseded.into()),
                    ]),
                ));
            }
            Json::obj(fields)
        }
        Some("list") => Json::obj(vec![
            ("ok", true.into()),
            ("version", env!("CARGO_PKG_VERSION").into()),
            (
                "benchmarks",
                Json::Arr(
                    BENCHMARKS.iter().map(|b| b.name().into()).collect(),
                ),
            ),
            (
                "models",
                Json::Arr(MODELS.iter().map(|m| m.name().into()).collect()),
            ),
            (
                "profiles",
                Json::Arr(
                    profiles::ALL.iter().map(|p| p.name.into()).collect(),
                ),
            ),
            (
                "timing_variants",
                Json::Arr(
                    profiles::TIMING_VARIANTS
                        .iter()
                        .map(|v| v.name.into())
                        .collect(),
                ),
            ),
        ]),
        Some("describe") => {
            let c = config_from(req);
            let what =
                req.get("what").and_then(Json::as_str).unwrap_or("datapath");
            let text = match what {
                "datapath" => describe::datapath(&c),
                "write-enable" => describe::write_enable(&c),
                "simd-alu" => describe::simd_alu(&c),
                "system" => describe::system(&c),
                other => {
                    return err_response(format!(
                        "unknown description `{other}`"
                    ))
                }
            };
            Json::obj(vec![("ok", true.into()), ("text", text.into())])
        }
        Some("bench") => {
            // Kernel name, `model:<name>`, or bare model name — one
            // axis.  Unknown names list everything that would parse.
            let workload = match req
                .get("benchmark")
                .and_then(Json::as_str)
                .map(WorkloadKind::parse)
            {
                Some(Ok(w)) => w,
                Some(Err(e)) => return err_response(e),
                None => {
                    return err_response(format!(
                        "missing `benchmark`; valid workloads: {}",
                        workload_names()
                    ))
                }
            };
            let Some(p) = req
                .get("profile")
                .and_then(Json::as_str)
                .and_then(Profile::by_name)
            else {
                return err_response("unknown profile");
            };
            let mode = match req.get("mode").and_then(Json::as_str) {
                Some("scalar") => Mode::Scalar,
                _ => Mode::Vector,
            };
            let seed = req.get("seed").and_then(Json::as_u64).unwrap_or(42);
            let point = EvalPoint {
                workload,
                profile: p,
                mode,
                config: config_from(req),
            };
            match evaluator.evaluate(&point, seed, analytic_limit_from(req)) {
                Ok(o) => {
                    let mut fields = vec![
                        ("ok", true.into()),
                        ("benchmark", workload.name().into()),
                        ("mode", mode.name().into()),
                        ("cycles", o.cycles.into()),
                        ("verified", o.verified.into()),
                        ("provenance", o.provenance.name().into()),
                        ("origin", o.origin.name().into()),
                        (
                            "scalar_instructions",
                            o.summary.scalar_instructions.into(),
                        ),
                        (
                            "vector_instructions",
                            o.summary.vector_instructions.into(),
                        ),
                    ];
                    // Model runs ship their per-stage sub-ledgers.
                    if !o.stages.is_empty() {
                        fields.push((
                            "stages",
                            crate::bench::store::stages_json(&o.stages),
                        ));
                    }
                    Json::obj(fields)
                }
                Err(e) => err_response(e),
            }
        }
        Some("sweep") => match sweep_spec_from(req) {
            Ok(spec) => {
                // Fold in peer appends first: workers sharing a cache
                // dir answer each other's shards from the store.
                evaluator.refresh_store();
                let started = std::time::Instant::now();
                let report = sweep::run_sweep_with(&spec, evaluator);
                let elapsed_ms = started.elapsed().as_secs_f64() * 1e3;
                stats.sweeps_served.fetch_add(1, Ordering::Relaxed);
                let Json::Obj(mut body) = sweep::report_json(&report) else {
                    unreachable!("report_json returns an object")
                };
                body.insert("ok".into(), true.into());
                // Measured wall-time closes the coordinator's cost
                // loop: shard responses report how long they really
                // took, and `run_cluster` re-budgets later shards
                // against the observed cost per estimated instruction.
                body.insert("elapsed_ms".into(), elapsed_ms.into());
                Json::Obj(body)
            }
            Err(e) => err_response(e),
        },
        Some("batch") => {
            let Some(requests) =
                req.get("requests").and_then(Json::as_arr)
            else {
                return err_response(
                    "`requests` must be an array of request objects",
                );
            };
            if requests.len() > MAX_BATCH_REQUESTS {
                return err_response(format!(
                    "batch of {} requests exceeds the {MAX_BATCH_REQUESTS}-request limit",
                    requests.len()
                ));
            }
            let responses: Vec<Json> = requests
                .iter()
                .map(|sub| {
                    if sub.get("cmd").and_then(Json::as_str) == Some("batch")
                    {
                        err_response("nested batch requests are not allowed")
                    } else {
                        handle_request_with(sub, evaluator, stats)
                    }
                })
                .collect();
            Json::obj(vec![
                ("ok", true.into()),
                ("count", (responses.len() as u64).into()),
                ("responses", Json::Arr(responses)),
            ])
        }
        // Observability: counters plus p50/p99/p999 latency per
        // command, straight off the process-wide histograms.  The
        // connection layer answers this inline (never queued), so stats
        // stay readable even when the pool is saturated.
        Some("stats") => Json::obj(vec![
            ("ok", true.into()),
            (
                "in_flight",
                (stats.in_flight.load(Ordering::Relaxed) as u64).into(),
            ),
            (
                "queue_depth",
                (stats.queue_depth.load(Ordering::Relaxed) as u64).into(),
            ),
            ("served", stats.served.load(Ordering::Relaxed).into()),
            ("rejected", stats.rejected.load(Ordering::Relaxed).into()),
            (
                "sweeps_served",
                stats.sweeps_served.load(Ordering::Relaxed).into(),
            ),
            ("latency_us", stats.latency_json()),
            // Interval window: everything recorded since the previous
            // `stats` call, then reset — loadgen and pollers get
            // per-window percentiles without tracking deltas.
            (
                "latency_window_us",
                stats.latency_window.snapshot_reset().summary_json(),
            ),
            // Connection-multiplexer health: sockets owned by the
            // poller and response bytes queued behind slow readers.
            (
                "poller",
                Json::obj(vec![
                    (
                        "fds",
                        (stats.poller_fds.load(Ordering::Relaxed) as u64)
                            .into(),
                    ),
                    (
                        "write_queue_bytes",
                        (stats.write_queue_bytes.load(Ordering::Relaxed)
                            as u64)
                            .into(),
                    ),
                ]),
            ),
            // Pool sizing: live vs target worker count plus how often
            // the autoscaler has moved it.
            (
                "workers",
                Json::obj(vec![
                    (
                        "current",
                        (stats.workers_current.load(Ordering::Relaxed)
                            as u64)
                            .into(),
                    ),
                    (
                        "target",
                        (stats.workers_target.load(Ordering::Relaxed)
                            as u64)
                            .into(),
                    ),
                    (
                        "grown",
                        crate::obs::metrics::AUTOSCALE_GROW.get().into(),
                    ),
                    (
                        "shrunk",
                        crate::obs::metrics::AUTOSCALE_SHRINK.get().into(),
                    ),
                ]),
            ),
            ("sessions", evaluator.sessions().stats_json()),
            ("model_sessions", evaluator.model_sessions().stats_json()),
            ("programs", (evaluator.programs().len() as u64).into()),
        ]),
        // Prometheus text exposition: the static obs registry plus this
        // server's live counters/gauges/latency summaries, carried as
        // the `body` string of a normal JSON response.  Answered inline
        // at the connection layer like `stats`.
        Some("metrics") => Json::obj(vec![
            ("ok", true.into()),
            ("content_type", "text/plain; version=0.0.4".into()),
            ("body", metrics_text(evaluator, stats).into()),
        ]),
        // Pre-warm the session pool over a sweep-shaped grid: build the
        // sealed sessions now so the first real request per point skips
        // the build cost.  Accepts the same axes as `sweep` (and the
        // same grid cap).
        Some("warm") => match sweep_spec_from(req) {
            Ok(spec) => {
                let mut warmed = 0u64;
                let mut errors = 0u64;
                for (point, _key) in spec.expand() {
                    match evaluator.warm_point(&point) {
                        Ok(()) => warmed += 1,
                        Err(_) => errors += 1,
                    }
                }
                Json::obj(vec![
                    ("ok", true.into()),
                    ("warmed", warmed.into()),
                    ("errors", errors.into()),
                    ("sessions", evaluator.sessions().stats_json()),
                    (
                        "model_sessions",
                        evaluator.model_sessions().stats_json(),
                    ),
                ])
            }
            Err(e) => err_response(e),
        },
        // Occupy one pool worker for a bounded interval.  A load-test
        // scaffold: it gives `arrow loadgen` (and the pipelining tests)
        // a request with a *known* service time, so saturation and
        // head-of-line behaviour are measurable deterministically.
        Some("sleep") => {
            let ms = req
                .get("ms")
                .and_then(Json::as_u64)
                .unwrap_or(0)
                .min(MAX_SLEEP_MS);
            std::thread::sleep(Duration::from_millis(ms));
            Json::obj(vec![("ok", true.into()), ("slept_ms", ms.into())])
        }
        // Real shutdowns are intercepted at the connection layer (they
        // need the peer address and the listener's drain flag); reaching
        // here means it was smuggled inside a batch or sent to the pure
        // handler.
        Some("shutdown") => err_response(
            "shutdown must be a top-level request on a loopback connection",
        ),
        other => err_response(format!(
            "unknown cmd {other:?} \
             (ping|list|shard|bench|sweep|batch|describe|stats|metrics|warm|sleep)"
        )),
    }
}

/// Parse a `sweep` request body into a [`SweepSpec`]; every unknown
/// name or malformed field is a client error, not a panic.
fn sweep_spec_from(req: &Json) -> Result<SweepSpec, String> {
    fn named_list<T>(
        req: &Json,
        key: &str,
        lookup: impl Fn(&str) -> Option<T>,
        unknown: impl Fn(&str) -> String,
    ) -> Result<Option<Vec<T>>, String> {
        let Some(v) = req.get(key) else { return Ok(None) };
        let arr = v
            .as_arr()
            .ok_or_else(|| format!("`{key}` must be an array of names"))?;
        let mut out = Vec::with_capacity(arr.len());
        for item in arr {
            let name = item
                .as_str()
                .ok_or_else(|| format!("`{key}` must be an array of names"))?;
            out.push(lookup(name).ok_or_else(|| unknown(name))?);
        }
        if out.is_empty() {
            return Err(format!("`{key}` must not be empty"));
        }
        Ok(Some(out))
    }

    fn num_list(req: &Json, key: &str) -> Result<Option<Vec<u64>>, String> {
        let Some(v) = req.get(key) else { return Ok(None) };
        let arr = v
            .as_arr()
            .ok_or_else(|| format!("`{key}` must be an array of numbers"))?;
        let out: Option<Vec<u64>> = arr.iter().map(Json::as_u64).collect();
        let out = out
            .ok_or_else(|| format!("`{key}` must be an array of numbers"))?;
        if out.is_empty() {
            return Err(format!("`{key}` must not be empty"));
        }
        Ok(Some(out))
    }

    /// [`num_list`] narrowed to u32 — out-of-range values are client
    /// errors, never silently truncated onto a *valid* width (2^32+32
    /// must not evaluate as ELEN 32).
    fn u32_list(req: &Json, key: &str) -> Result<Option<Vec<u32>>, String> {
        let Some(v) = num_list(req, key)? else { return Ok(None) };
        v.into_iter()
            .map(|n| {
                u32::try_from(n)
                    .map_err(|_| format!("`{key}` value {n} out of range"))
            })
            .collect::<Result<Vec<u32>, String>>()
            .map(Some)
    }

    let mut spec = SweepSpec::default();
    // Unknown workload names list everything that would parse —
    // kernels and models — instead of a bare "unknown benchmark".
    let unknown_workload = |kind: &str| {
        move |name: &str| {
            format!(
                "unknown {kind} `{name}`; valid workloads: {}",
                workload_names()
            )
        }
    };
    if let Some(b) = named_list(
        req,
        "benchmarks",
        Benchmark::by_name,
        unknown_workload("benchmark"),
    )? {
        spec.benchmarks = b;
    }
    if let Some(m) = named_list(
        req,
        "models",
        ModelId::by_name,
        unknown_workload("model"),
    )? {
        spec.models = m;
    }
    if let Some(p) = named_list(req, "profiles", Profile::by_name, |n| {
        format!("unknown profile `{n}`")
    })? {
        spec.profiles = p;
    }
    if let Some(m) = named_list(req, "modes", Mode::by_name, |n| {
        format!("unknown mode `{n}`")
    })? {
        spec.modes = m;
    }
    if let Some(l) = num_list(req, "lanes")? {
        spec.lanes = l.into_iter().map(|n| n as usize).collect();
    }
    if let Some(v) = u32_list(req, "vlens")? {
        spec.vlens = v;
    }
    if let Some(e) = u32_list(req, "elens")? {
        spec.elens = e;
    }
    if let Some(t) =
        named_list(req, "timing", TimingVariant::by_name, |n| {
            format!("unknown timing variant `{n}`")
        })?
    {
        spec.timing = t;
    }
    if let Some(t) = req.get("threads").and_then(Json::as_u64) {
        spec.threads = t as usize;
    }
    if let Some(s) = req.get("seed").and_then(Json::as_u64) {
        spec.seed = s;
    }
    // Lockstep batch width: 0 (or absent) means auto; 1 disables
    // batching — the same contract as the CLI `--batch-width` flag.
    if let Some(w) = req.get("batch_width").and_then(Json::as_u64) {
        spec.batch_width = (w > 0).then_some(w as usize);
    }
    spec.analytic_limit = analytic_limit_from(req);
    let grid = spec.grid_len();
    if grid > MAX_SWEEP_GRID {
        return Err(format!(
            "sweep grid of {grid} points exceeds the {MAX_SWEEP_GRID}-point limit"
        ));
    }
    Ok(spec)
}

/// Analytic-routing threshold of one request: `"analytic_limit": N`
/// overrides, `"no_analytic": true` forces exact simulation, default is
/// the crate-wide [`crate::bench::analytic::SIM_LIMIT`].
fn analytic_limit_from(req: &Json) -> Option<u64> {
    if req.get("no_analytic").and_then(Json::as_bool) == Some(true) {
        return None;
    }
    match req.get("analytic_limit").and_then(Json::as_u64) {
        Some(limit) => Some(limit),
        None => Some(crate::bench::analytic::SIM_LIMIT),
    }
}

fn config_from(req: &Json) -> ArrowConfig {
    let mut c = ArrowConfig::default();
    if let Some(lanes) = req.get("lanes").and_then(Json::as_u64) {
        c.lanes = lanes as usize;
    }
    if let Some(vlen) = req.get("vlen").and_then(Json::as_u64) {
        c.vlen_bits = vlen as u32;
    }
    c
}

/// Everything one server process shares across its connections.
struct ServerCore {
    evaluator: Evaluator,
    stats: ServerStats,
    executor: Executor,
    /// Set by `{"cmd": "shutdown"}`; the accept loop polls it (and the
    /// process-wide SIGTERM flag) and drains when either fires.
    shutdown: AtomicBool,
}

impl ServerCore {
    fn new(evaluator: Evaluator, exec: ExecutorOptions) -> ServerCore {
        ServerCore {
            evaluator,
            stats: ServerStats::default(),
            executor: Executor::new(exec),
            shutdown: AtomicBool::new(false),
        }
    }
}

/// Where a response goes: tagged requests (an `"id"` field) are queued
/// for write the moment they complete; untagged requests hold a
/// sequence number and are delivered strictly in request order through
/// the reorder buffer.
#[derive(Debug, Clone, Copy)]
enum Slot {
    Ordered(u64),
    Tagged,
}

/// Wakes the poller from pool workers: a self-connected loopback TCP
/// pair, so no extra FFI surface is needed.  A completed job writes one
/// byte to `tx`; the poller — parked in `poll(2)` — sees `rx` readable,
/// drains it, and flushes the write queues the job appended to.
struct Waker {
    tx: TcpStream,
    rx: TcpStream,
}

impl Waker {
    fn new() -> std::io::Result<Waker> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let tx = TcpStream::connect(listener.local_addr()?)?;
        let (rx, _) = listener.accept()?;
        tx.set_nonblocking(true)?;
        rx.set_nonblocking(true)?;
        Ok(Waker { tx, rx })
    }

    /// Nudge the poller.  A full pipe (or any write error) is fine:
    /// wake bytes are level-triggered hints, and a full pipe means the
    /// poller has wakes pending already.
    fn wake(&self) {
        let _ = (&self.tx).write(&[1u8]);
    }

    /// Swallow queued wake bytes (poller side).
    fn drain(&self) {
        let mut buf = [0u8; 64];
        while matches!((&self.rx).read(&mut buf), Ok(n) if n > 0) {}
    }
}

/// Per-connection writer state: the reorder buffer for in-order
/// (untagged) responses plus the bounded write queue the poller flushes
/// on writability.  Pool workers completing out of order park their
/// rendered response in `pending`; whoever completes the next expected
/// sequence moves the run into `wbuf`.
struct ConnOut {
    /// Rendered-but-unwritten response bytes (newline-terminated).
    wbuf: Vec<u8>,
    next_seq: u64,
    pending: BTreeMap<u64, String>,
    /// The peer's write side failed; deliveries are dropped.
    dead: bool,
}

/// Connection state shared between the poller and pool workers.
struct ConnShared {
    out: Mutex<ConnOut>,
    /// Admitted-but-undelivered executor jobs for this connection; the
    /// poller keeps the socket alive while this is non-zero.
    jobs: AtomicUsize,
    waker: Arc<Waker>,
}

impl ConnShared {
    fn new(waker: Arc<Waker>) -> ConnShared {
        ConnShared {
            out: Mutex::new(ConnOut {
                wbuf: Vec::new(),
                next_seq: 0,
                pending: BTreeMap::new(),
                dead: false,
            }),
            jobs: AtomicUsize::new(0),
            waker,
        }
    }
}

/// Balances the per-connection job counter by drop, so a panicking
/// request handler cannot pin its connection in the poller forever.
/// The final wake makes the poller re-check the connection even when
/// the delivery itself was skipped (dead peer).
struct JobGuard(Arc<ConnShared>);

impl Drop for JobGuard {
    fn drop(&mut self) {
        self.0.jobs.fetch_sub(1, Ordering::AcqRel);
        self.0.waker.wake();
    }
}

fn lock_out(out: &Mutex<ConnOut>) -> std::sync::MutexGuard<'_, ConnOut> {
    out.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Deliver one response into its slot: render into the connection's
/// write queue (reorder-buffer semantics preserved) and wake the poller
/// to flush it.
fn deliver(shared: &ConnShared, slot: Slot, resp: &Json) {
    let mut o = lock_out(&shared.out);
    if o.dead {
        return;
    }
    match slot {
        Slot::Tagged => {
            let line = resp.to_string();
            o.wbuf.reserve(line.len() + 1);
            o.wbuf.extend_from_slice(line.as_bytes());
            o.wbuf.push(b'\n');
        }
        Slot::Ordered(seq) => {
            o.pending.insert(seq, resp.to_string());
            loop {
                let next = o.next_seq;
                let Some(line) = o.pending.remove(&next) else { break };
                o.next_seq += 1;
                o.wbuf.reserve(line.len() + 1);
                o.wbuf.extend_from_slice(line.as_bytes());
                o.wbuf.push(b'\n');
            }
        }
    }
    drop(o);
    shared.waker.wake();
}

/// Echo the request's `"id"` into the response, so a pipelining client
/// can match out-of-order completions.
fn attach_id(resp: Json, id: Option<Json>) -> Json {
    match (resp, id) {
        (Json::Obj(mut m), Some(id)) => {
            m.insert("id".to_string(), id);
            Json::Obj(m)
        }
        (resp, _) => resp,
    }
}

/// The structured admission-control rejection: `busy: true` is the
/// machine-readable signal (clients retry/shed on it; the error string
/// is for humans).
fn busy_response(reject: &Reject) -> Json {
    Json::obj(vec![
        ("ok", false.into()),
        ("busy", true.into()),
        ("error", Json::Str(reject.to_string())),
    ])
}

/// The structured rejection for a connection whose write queue exceeds
/// [`WRITE_QUEUE_CAP`]: the same `busy: true` contract as executor
/// admission control, different bottleneck — the client is pipelining
/// requests faster than it reads responses.
fn overflow_response(queued: usize) -> Json {
    Json::obj(vec![
        ("ok", false.into()),
        ("busy", true.into()),
        (
            "error",
            Json::Str(format!(
                "server busy: connection write queue full \
                 ({queued} bytes unread)"
            )),
        ),
    ])
}

/// One multiplexed connection as the poller sees it.
struct Conn {
    stream: TcpStream,
    peer: Option<SocketAddr>,
    /// Partial-line accumulator between readiness events.
    rbuf: Vec<u8>,
    /// Next untagged sequence number to assign.
    seq: u64,
    shared: Arc<ConnShared>,
    /// EOF observed; the socket closes once admitted work drains.
    closed_read: bool,
}

/// Handle one complete request line: parse, assign its [`Slot`], answer
/// admin/observability inline on the poller thread, and admit the rest
/// to the shared pool — the same routing the per-connection reader
/// threads used to do, minus the threads.
fn process_line(core: &Arc<ServerCore>, conn: &mut Conn, line: &str) {
    if line.trim().is_empty() {
        return;
    }
    let req = match json::parse(line) {
        Ok(r) => r,
        Err(e) => {
            let slot = Slot::Ordered(conn.seq);
            conn.seq += 1;
            deliver(
                &conn.shared,
                slot,
                &err_response(format!("bad json: {e}")),
            );
            return;
        }
    };
    let id = req.get("id").cloned();
    let slot = if id.is_some() {
        Slot::Tagged
    } else {
        let s = Slot::Ordered(conn.seq);
        conn.seq += 1;
        s
    };
    let cmd = req.get("cmd").and_then(Json::as_str);
    // Admin: flip the server-wide drain flag.  Loopback peers only — a
    // worker's serve port is reachable from the whole fleet, and any
    // remote being able to stop it would turn a typo into an outage.
    if cmd == Some("shutdown") {
        let resp = if conn.peer.is_some_and(|p| p.ip().is_loopback()) {
            core.shutdown.store(true, Ordering::Release);
            Json::obj(vec![("ok", true.into()), ("draining", true.into())])
        } else {
            err_response("shutdown is admin-only (loopback connections)")
        };
        deliver(&conn.shared, slot, &attach_id(resp, id));
        return;
    }
    // Slow-reader backpressure: past the write-queue cap every further
    // request answers a small constant-size `busy` line instead of
    // queueing a real response body behind a peer that isn't reading.
    let queued = lock_out(&conn.shared.out).wbuf.len();
    if queued > WRITE_QUEUE_CAP {
        crate::obs::metrics::CONN_WRITE_SHED.inc();
        core.stats.rejected.fetch_add(1, Ordering::Relaxed);
        deliver(
            &conn.shared,
            slot,
            &attach_id(overflow_response(queued), id),
        );
        return;
    }
    // Observability must not queue behind the load it is measuring:
    // answer on the poller thread.
    if matches!(cmd, Some("stats") | Some("metrics")) {
        let started = Instant::now();
        let resp = handle_request_with(&req, &core.evaluator, &core.stats);
        core.stats.record(kind_of(cmd), started.elapsed());
        deliver(&conn.shared, slot, &attach_id(resp, id));
        return;
    }
    let kind = kind_of(cmd);
    let core_job = Arc::clone(core);
    let shared_job = Arc::clone(&conn.shared);
    let id_job = id.clone();
    let admitted = Instant::now();
    conn.shared.jobs.fetch_add(1, Ordering::AcqRel);
    let submitted = core.executor.submit(move || {
        let _job_guard = JobGuard(Arc::clone(&shared_job));
        core_job.stats.record_queue_wait(admitted.elapsed());
        let _guard = InFlightGuard::new(&core_job.stats);
        core_job
            .stats
            .queue_depth
            .store(core_job.executor.queue_len(), Ordering::Relaxed);
        let resp =
            handle_request_with(&req, &core_job.evaluator, &core_job.stats);
        core_job.stats.record(kind, admitted.elapsed());
        deliver(&shared_job, slot, &attach_id(resp, id_job));
    });
    match submitted {
        Ok(()) => {
            core.stats
                .queue_depth
                .store(core.executor.queue_len(), Ordering::Relaxed);
        }
        Err(reject) => {
            conn.shared.jobs.fetch_sub(1, Ordering::AcqRel);
            core.stats.rejected.fetch_add(1, Ordering::Relaxed);
            deliver(
                &conn.shared,
                slot,
                &attach_id(busy_response(&reject), id),
            );
        }
    }
}

/// Drain readable bytes from one connection and process every complete
/// line.  Partial tails stay buffered for the next readiness event; EOF
/// and hard errors mark the read side closed.
fn read_conn(core: &Arc<ServerCore>, conn: &mut Conn) {
    let mut buf = [0u8; 16 * 1024];
    loop {
        match (&conn.stream).read(&mut buf) {
            Ok(0) => {
                conn.closed_read = true;
                break;
            }
            Ok(n) => {
                conn.rbuf.extend_from_slice(&buf[..n]);
                let mut start = 0usize;
                loop {
                    let Some(rel) =
                        conn.rbuf[start..].iter().position(|&b| b == b'\n')
                    else {
                        break;
                    };
                    let mut end = start + rel;
                    // Tolerate CRLF like the old BufRead::lines reader.
                    if end > start && conn.rbuf[end - 1] == b'\r' {
                        end -= 1;
                    }
                    let line =
                        String::from_utf8_lossy(&conn.rbuf[start..end])
                            .into_owned();
                    start += rel + 1;
                    process_line(core, conn, &line);
                }
                conn.rbuf.drain(..start);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => {
                conn.closed_read = true;
                lock_out(&conn.shared.out).dead = true;
                break;
            }
        }
    }
}

/// Flush as much queued output as the socket accepts right now.  A
/// write error marks the connection dead and drops its queue — the
/// peer is gone.
fn flush_conn(conn: &Conn) {
    let mut o = lock_out(&conn.shared.out);
    while !o.wbuf.is_empty() {
        match (&conn.stream).write(&o.wbuf) {
            Ok(0) => {
                o.dead = true;
                o.wbuf.clear();
                break;
            }
            Ok(n) => {
                o.wbuf.drain(..n);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => {
                o.dead = true;
                o.wbuf.clear();
                break;
            }
        }
    }
}

/// Whether the poller can drop this socket.  Reads the job counter
/// *first*: observing zero (Acquire) means every delivery that will
/// ever happen is already visible in the write queue, so the
/// empty-queue check that follows cannot race a late completion.
fn conn_finished(conn: &Conn) -> bool {
    if conn.shared.jobs.load(Ordering::Acquire) != 0 {
        return false;
    }
    let o = lock_out(&conn.shared.out);
    if o.dead {
        return true;
    }
    conn.closed_read && o.wbuf.is_empty() && o.pending.is_empty()
}

/// The readiness-polled multiplexer: one thread owns the listener, the
/// waker, and every accepted socket.  Replaces the
/// one-reader-thread-per-connection model — the OS-thread count is now
/// the poller plus the (autoscaled) executor pool, independent of how
/// many clients are connected.  Returns after a shutdown request or
/// SIGTERM has been observed, the executor has drained (bounded by
/// [`SHUTDOWN_GRACE`]), and pending responses are flushed.
fn run_poller(
    listener: TcpListener,
    core: &Arc<ServerCore>,
) -> std::io::Result<()> {
    listener.set_nonblocking(true)?;
    let waker = Arc::new(Waker::new()?);
    let mut conns: Vec<Conn> = Vec::new();
    // The executor drain runs on a helper thread so the poller keeps
    // flushing write queues while in-flight jobs finish.
    let mut drain: Option<(std::thread::JoinHandle<()>, Arc<AtomicBool>)> =
        None;
    loop {
        let draining =
            core.shutdown.load(Ordering::Acquire) || sigterm_pending();
        if draining && drain.is_none() {
            crate::obs_info!(
                "server",
                "draining: waiting up to {}s for in-flight requests",
                SHUTDOWN_GRACE.as_secs()
            );
            let done = Arc::new(AtomicBool::new(false));
            let exec_core = Arc::clone(core);
            let exec_done = Arc::clone(&done);
            let exec_waker = Arc::clone(&waker);
            let handle = std::thread::spawn(move || {
                if exec_core.executor.shutdown(SHUTDOWN_GRACE) {
                    crate::obs_info!("server", "drained cleanly; exiting");
                } else {
                    crate::obs_warn!(
                        "server",
                        "drain grace expired with requests still running"
                    );
                }
                exec_done.store(true, Ordering::Release);
                exec_waker.wake();
            });
            drain = Some((handle, done));
        }
        if let Some((_, done)) = &drain {
            if done.load(Ordering::Acquire) {
                // Final flush: give the queued responses a bounded
                // window to reach their sockets, then exit.
                let deadline = Instant::now() + Duration::from_secs(2);
                loop {
                    let mut queued = 0usize;
                    for conn in &conns {
                        flush_conn(conn);
                        queued += lock_out(&conn.shared.out).wbuf.len();
                    }
                    if queued == 0 || Instant::now() >= deadline {
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                break;
            }
        }
        // Build the descriptor set: listener (accept interest until
        // draining), waker, then one entry per connection.
        let mut fds = Vec::with_capacity(conns.len() + 2);
        fds.push(PollFd::new(
            listener.raw_fd(),
            if draining { 0 } else { POLLIN },
        ));
        fds.push(PollFd::new(waker.rx.raw_fd(), POLLIN));
        for conn in &conns {
            let mut events = 0i16;
            if !conn.closed_read {
                events |= POLLIN;
            }
            if !lock_out(&conn.shared.out).wbuf.is_empty() {
                events |= POLLOUT;
            }
            fds.push(PollFd::new(conn.stream.raw_fd(), events));
        }
        poll::poll(&mut fds, POLL_TICK)?;
        if fds[1].readable() {
            waker.drain();
        }
        if fds[0].readable() && !draining {
            loop {
                match listener.accept() {
                    Ok((stream, peer)) => {
                        if stream.set_nonblocking(true).is_err() {
                            continue;
                        }
                        let _ = stream.set_nodelay(true);
                        crate::obs::metrics::CONN_ACCEPTED.inc();
                        conns.push(Conn {
                            stream,
                            peer: Some(peer),
                            rbuf: Vec::new(),
                            seq: 0,
                            shared: Arc::new(ConnShared::new(Arc::clone(
                                &waker,
                            ))),
                            closed_read: false,
                        });
                    }
                    Err(e)
                        if e.kind()
                            == std::io::ErrorKind::WouldBlock =>
                    {
                        break
                    }
                    Err(e)
                        if e.kind()
                            == std::io::ErrorKind::Interrupted =>
                    {
                        break
                    }
                    Err(e) => {
                        crate::obs_error!("server", "accept: {e}");
                        break;
                    }
                }
            }
        }
        // Per-connection events; `fds[2..]` is index-aligned with
        // `conns` (connections accepted above were not polled yet, so
        // they are past the end of this slice and wait one tick).
        for (i, fd) in fds.iter().skip(2).enumerate() {
            let conn = &mut conns[i];
            if fd.readable() && !conn.closed_read {
                read_conn(core, conn);
            }
            // Flush opportunistically: POLLOUT readiness, or fresh
            // output appended after the interest set was built.
            flush_conn(conn);
        }
        // Retire finished connections and refresh the poller gauges.
        let mut write_queued = 0usize;
        conns.retain(|conn| {
            if conn_finished(conn) {
                if let Some(peer) = conn.peer {
                    crate::obs_info!(
                        "server",
                        "connection from {peer} closed"
                    );
                }
                false
            } else {
                write_queued += lock_out(&conn.shared.out).wbuf.len();
                true
            }
        });
        core.stats.poller_fds.store(conns.len(), Ordering::Relaxed);
        core.stats
            .write_queue_bytes
            .store(write_queued, Ordering::Relaxed);
        core.stats
            .workers_current
            .store(core.executor.worker_count(), Ordering::Relaxed);
        core.stats
            .workers_target
            .store(core.executor.target_workers(), Ordering::Relaxed);
    }
    if let Some((handle, _)) = drain {
        let _ = handle.join();
    }
    core.stats.poller_fds.store(0, Ordering::Relaxed);
    Ok(())
}

/// Process-wide SIGTERM flag (one per process, like the signal itself);
/// the accept loop of every serving listener polls it.
static SIGTERM_FLAG: AtomicBool = AtomicBool::new(false);

fn sigterm_pending() -> bool {
    SIGTERM_FLAG.load(Ordering::Acquire)
}

/// Install the SIGTERM handler (once).  Raw `signal(2)` FFI: the build
/// is dependency-free, and all the handler does is set an atomic flag —
/// async-signal-safe by construction.
#[cfg(unix)]
fn install_sigterm() {
    use std::sync::Once;
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        extern "C" fn on_sigterm(_sig: i32) {
            SIGTERM_FLAG.store(true, Ordering::Release);
        }
        extern "C" {
            fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
        }
        const SIGTERM: i32 = 15;
        unsafe {
            let _ = signal(SIGTERM, on_sigterm);
        }
    });
}

#[cfg(not(unix))]
fn install_sigterm() {}

/// Session-pool headroom per executor worker: the autoscaler retargets
/// the pool cap to `workers * SESSIONS_PER_WORKER` (bounded by the
/// static [`crate::bench::eval::SESSION_POOL_CAP`]).
pub const SESSIONS_PER_WORKER: usize = 64;

/// Autoscaler policy (`arrow serve --workers-min/--workers-max`): a
/// control loop drains the queue-wait histogram window every
/// `interval` and resizes the executor pool inside
/// `[min_workers, max_workers]`.
#[derive(Debug, Clone)]
pub struct AutoscaleSpec {
    pub min_workers: usize,
    pub max_workers: usize,
    /// Control-loop tick (and therefore the histogram window width).
    pub interval: Duration,
    /// Queue-wait p90 (µs) above which a window counts as
    /// under-provisioned.
    pub grow_p90_us: u64,
}

impl AutoscaleSpec {
    pub fn new(min_workers: usize, max_workers: usize) -> AutoscaleSpec {
        let min_workers = min_workers.max(1);
        AutoscaleSpec {
            min_workers,
            max_workers: max_workers.max(min_workers),
            interval: Duration::from_millis(500),
            grow_p90_us: 5_000,
        }
    }
}

/// The autoscaler control loop, one tick per `spec.interval` until the
/// server drains.  Grow (by half the current pool, at least one) after
/// two consecutive windows whose queue-wait p90 exceeds the threshold
/// — one hot window is a burst, two is a trend; shrink one worker
/// after two consecutive fully-idle windows.  Every resize retargets
/// the session pool alongside and emits a trace instant plus the
/// grow/shrink counters.
fn autoscale_loop(core: &Arc<ServerCore>, spec: &AutoscaleSpec) {
    use crate::obs::{metrics, trace};
    // Pin the pool inside the configured band up front.
    let current = core.executor.worker_count();
    let clamped = current.clamp(spec.min_workers, spec.max_workers);
    if clamped != current {
        core.executor.resize(clamped);
    }
    let mut hot = 0u32;
    let mut idle = 0u32;
    while !(core.shutdown.load(Ordering::Acquire) || sigterm_pending()) {
        std::thread::sleep(spec.interval);
        let window = core.stats.drain_queue_wait_window();
        let current = core.executor.worker_count();
        let p90 = window.quantile_us(0.90);
        let busy = window.count() > 0
            || core.executor.queue_len() > 0
            || core.stats.in_flight.load(Ordering::Relaxed) > 0;
        if window.count() > 0 && p90 > spec.grow_p90_us {
            hot += 1;
            idle = 0;
        } else if !busy {
            idle += 1;
            hot = 0;
        } else {
            hot = 0;
            idle = 0;
        }
        let mut target = current;
        if hot >= 2 {
            target = (current + (current / 2).max(1)).min(spec.max_workers);
            hot = 0;
        } else if idle >= 2 {
            target = current.saturating_sub(1).max(spec.min_workers);
            idle = 0;
        }
        if target == current {
            continue;
        }
        let applied = core.executor.resize(target);
        if applied > current {
            metrics::AUTOSCALE_GROW.inc();
        } else {
            metrics::AUTOSCALE_SHRINK.inc();
        }
        // The session pool scales with the workers that fill it: each
        // worker gets headroom for its own working set.
        core.evaluator.sessions().set_cap(
            (applied * SESSIONS_PER_WORKER)
                .clamp(SESSIONS_PER_WORKER, crate::bench::eval::SESSION_POOL_CAP),
        );
        core.stats.workers_target.store(applied, Ordering::Relaxed);
        core.stats
            .workers_current
            .store(core.executor.worker_count(), Ordering::Relaxed);
        trace::instant(
            "server",
            "autoscale",
            &[
                ("from", trace::Arg::U64(current as u64)),
                ("to", trace::Arg::U64(applied as u64)),
                ("queue_wait_p90_us", trace::Arg::U64(p90)),
                ("window_count", trace::Arg::U64(window.count())),
            ],
        );
        crate::obs_info!(
            "server",
            "autoscale: {current} -> {applied} workers \
             (queue-wait p90 {p90}µs over {} samples)",
            window.count()
        );
    }
}

/// Serve on `addr` (e.g. `127.0.0.1:7676`) with the default executor
/// sizing.  All connections share one [`Evaluator`]; passing a
/// `cache_dir` additionally backs it with the persistent result store
/// (an unopenable store is reported and the server runs uncached).
/// With a [`JoinSpec`] the worker also announces itself to a fleet
/// coordinator and keeps heartbeating (`arrow serve --join`).  Returns
/// after a graceful shutdown (`{"cmd": "shutdown"}` or SIGTERM) drains
/// in-flight requests.
pub fn serve(
    addr: &str,
    cache_dir: Option<&Path>,
    join: Option<&JoinSpec>,
) -> std::io::Result<()> {
    serve_opts(addr, cache_dir, join, ExecutorOptions::default())
}

/// [`serve`] with explicit executor sizing (`arrow serve --workers N
/// --queue-depth M`).
pub fn serve_opts(
    addr: &str,
    cache_dir: Option<&Path>,
    join: Option<&JoinSpec>,
    exec: ExecutorOptions,
) -> std::io::Result<()> {
    serve_scaled(addr, cache_dir, join, exec, None)
}

/// [`serve_opts`] plus the histogram-driven autoscaler (`arrow serve
/// --workers-min N --workers-max M`).
pub fn serve_scaled(
    addr: &str,
    cache_dir: Option<&Path>,
    join: Option<&JoinSpec>,
    exec: ExecutorOptions,
    autoscale: Option<AutoscaleSpec>,
) -> std::io::Result<()> {
    let listener = TcpListener::bind(addr)?;
    crate::obs_info!("server", "arrow simulator serving on {addr}");
    serve_listener_scaled(listener, cache_dir, join, exec, autoscale)
}

/// [`serve`] on an already-bound listener.  The in-process worker
/// fleets of the cluster tests bind port 0 themselves (to learn the
/// real address before serving) and hand the listener here.
pub fn serve_listener(
    listener: TcpListener,
    cache_dir: Option<&Path>,
) -> std::io::Result<()> {
    serve_listener_opts(listener, cache_dir, None, ExecutorOptions::default())
}

/// [`serve_listener`] with optional fleet membership.
pub fn serve_listener_with(
    listener: TcpListener,
    cache_dir: Option<&Path>,
    join: Option<&JoinSpec>,
) -> std::io::Result<()> {
    serve_listener_opts(listener, cache_dir, join, ExecutorOptions::default())
}

/// The full serving path: bounded executor + pipelined connections +
/// optional fleet membership (a detached announcer registers this
/// worker with the coordinator and re-registers every `join.interval` —
/// each heartbeat carrying the live load counters, queue depth and
/// ledger stats — until the process exits or the coordinator refuses
/// the registration).  Returns once a shutdown request or SIGTERM has
/// been observed and the pool has drained (bounded by
/// [`SHUTDOWN_GRACE`]).
pub fn serve_listener_opts(
    listener: TcpListener,
    cache_dir: Option<&Path>,
    join: Option<&JoinSpec>,
    exec: ExecutorOptions,
) -> std::io::Result<()> {
    serve_listener_scaled(listener, cache_dir, join, exec, None)
}

/// [`serve_listener_opts`] plus the optional autoscaler loop.
pub fn serve_listener_scaled(
    listener: TcpListener,
    cache_dir: Option<&Path>,
    join: Option<&JoinSpec>,
    exec: ExecutorOptions,
    autoscale: Option<AutoscaleSpec>,
) -> std::io::Result<()> {
    let mut evaluator = Evaluator::new();
    if let Some(dir) = cache_dir {
        match ResultStore::open(dir) {
            Ok(store) => {
                crate::obs_info!(
                    "server",
                    "result store at {} ({} entries)",
                    store.path().display(),
                    store.len()
                );
                evaluator.attach_store(store);
            }
            Err(e) => crate::obs_warn!(
                "server",
                "cache dir {}: {e} (serving uncached)",
                dir.display()
            ),
        }
    }
    let core = Arc::new(ServerCore::new(evaluator, exec));
    crate::obs_info!(
        "server",
        "executor: {} workers, queue depth {}",
        core.executor.worker_count(),
        core.executor.queue_cap()
    );
    if let Some(join) = join {
        let advertise = match &join.advertise {
            Some(a) => a.clone(),
            None => listener.local_addr()?.to_string(),
        };
        crate::obs_info!(
            "server",
            "joining fleet at {} as {advertise}",
            join.coordinator
        );
        let payload_core = Arc::clone(&core);
        fleet::announce(
            join.coordinator.clone(),
            join.interval,
            move || {
                register_payload(
                    &advertise,
                    &payload_core.evaluator,
                    &payload_core.stats,
                )
            },
        );
    }
    install_sigterm();
    core.stats
        .workers_current
        .store(core.executor.worker_count(), Ordering::Relaxed);
    core.stats
        .workers_target
        .store(core.executor.target_workers(), Ordering::Relaxed);
    let scaler = autoscale.map(|spec| {
        crate::obs_info!(
            "server",
            "autoscaler: {}..{} workers, {}ms window",
            spec.min_workers,
            spec.max_workers,
            spec.interval.as_millis()
        );
        let scaler_core = Arc::clone(&core);
        std::thread::spawn(move || autoscale_loop(&scaler_core, &spec))
    });
    let result = run_poller(listener, &core);
    // Stop the autoscaler even when the poller exited on an error
    // rather than the drain flag.
    core.shutdown.store(true, Ordering::Release);
    if let Some(handle) = scaler {
        let _ = handle.join();
    }
    result
}

/// The `{"cmd": "register"}` body one heartbeat carries: identity,
/// version, request caps, live load, and (when a store is attached)
/// ledger health — everything the coordinator's membership table
/// tracks per worker.
pub fn register_payload(
    advertise: &str,
    evaluator: &Evaluator,
    stats: &ServerStats,
) -> Json {
    let mut fields = vec![
        ("cmd", "register".into()),
        ("addr", advertise.into()),
        ("version", env!("CARGO_PKG_VERSION").into()),
        ("max_grid", (MAX_SWEEP_GRID as u64).into()),
        ("max_batch", (MAX_BATCH_REQUESTS as u64).into()),
        ("load", stats.load_json()),
    ];
    if let Some(store) = evaluator.store() {
        let s = store.stats();
        fields.push((
            "ledger",
            Json::obj(vec![
                ("entries", (s.entries as u64).into()),
                ("bytes", s.bytes.into()),
                ("superseded", s.superseded.into()),
            ]),
        ));
    }
    Json::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(s: &str) -> Json {
        json::parse(s).unwrap()
    }

    /// One-shot handler with a fresh evaluator (tests that exercise
    /// evaluator reuse build their own).
    fn handle(s: &str) -> Json {
        handle_request(&req(s), &Evaluator::new())
    }

    #[test]
    fn ping() {
        let r = handle(r#"{"cmd": "ping"}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    }

    #[test]
    fn bench_roundtrip() {
        let r = handle(
            r#"{"cmd": "bench", "benchmark": "vector_addition",
                "profile": "test", "mode": "vector"}"#,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        assert_eq!(r.get("verified"), Some(&Json::Bool(true)));
        assert_eq!(
            r.get("provenance").unwrap().as_str(),
            Some("simulated")
        );
        assert!(r.get("cycles").unwrap().as_u64().unwrap() > 0);
    }

    #[test]
    fn list_profiles_derived_from_registry() {
        let r = handle(r#"{"cmd": "list"}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        let names: Vec<&str> = r
            .get("profiles")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|p| p.as_str().unwrap())
            .collect();
        let registry: Vec<&str> =
            profiles::ALL.iter().map(|p| p.name).collect();
        assert_eq!(names, registry);
    }

    #[test]
    fn shard_handshake_advertises_version_and_caps() {
        let r = handle(r#"{"cmd": "shard"}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(
            r.get("version").unwrap().as_str(),
            Some(env!("CARGO_PKG_VERSION"))
        );
        assert_eq!(
            r.get("max_grid").unwrap().as_u64(),
            Some(MAX_SWEEP_GRID as u64)
        );
        assert_eq!(
            r.get("max_batch").unwrap().as_u64(),
            Some(MAX_BATCH_REQUESTS as u64)
        );
        // A storeless evaluator says so.
        assert_eq!(r.get("store"), Some(&Json::Bool(false)));
        // And the list response carries the same version, so older
        // clients that only speak `list` can still detect a mismatch.
        let l = handle(r#"{"cmd": "list"}"#);
        assert_eq!(
            l.get("version").unwrap().as_str(),
            Some(env!("CARGO_PKG_VERSION"))
        );
    }

    #[test]
    fn unknown_cmd_rejected() {
        let r = handle(r#"{"cmd": "nuke"}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        let msg = r.get("error").unwrap().as_str().unwrap();
        assert!(msg.contains("unknown cmd"), "{msg}");
    }

    #[test]
    fn missing_cmd_rejected() {
        let r = handle(r#"{"benchmark": "vector_addition"}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn unknown_benchmark_rejected_with_valid_names() {
        let r = handle(
            r#"{"cmd": "bench", "benchmark": "quicksort", "profile": "test"}"#,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        // The error tells the caller what *would* parse: every kernel
        // and every model, not a bare "unknown benchmark".
        let msg = r.get("error").unwrap().as_str().unwrap();
        assert!(msg.contains("quicksort"), "{msg}");
        assert!(msg.contains("vector_addition"), "{msg}");
        assert!(msg.contains("model:tinycnn"), "{msg}");
        // Same contract on the sweep axes, both fields.
        for body in [
            r#"{"cmd": "sweep", "benchmarks": ["quicksort"]}"#,
            r#"{"cmd": "sweep", "models": ["resnet"]}"#,
        ] {
            let r = handle(body);
            assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "{body}");
            let msg = r.get("error").unwrap().as_str().unwrap();
            assert!(msg.contains("model:tinycnn"), "{msg}");
        }
    }

    #[test]
    fn bench_runs_a_model_end_to_end() {
        let r = handle(
            r#"{"cmd": "bench", "benchmark": "model:vecchain",
                "profile": "test", "mode": "vector"}"#,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        assert_eq!(
            r.get("benchmark").unwrap().as_str(),
            Some("model:vecchain")
        );
        assert_eq!(r.get("verified"), Some(&Json::Bool(true)));
        let total = r.get("cycles").unwrap().as_u64().unwrap();
        assert!(total > 0);
        // The per-stage sub-ledgers ride the response and sum exactly.
        let stages = r.get("stages").unwrap().as_arr().unwrap();
        assert_eq!(stages.len(), 3);
        let sum: u64 = stages
            .iter()
            .map(|s| s.get("cycles").unwrap().as_u64().unwrap())
            .sum();
        assert_eq!(sum, total);
        // Bare model names parse too.
        let bare = handle(
            r#"{"cmd": "bench", "benchmark": "vecchain",
                "profile": "test", "mode": "vector"}"#,
        );
        assert_eq!(bare.get("ok"), Some(&Json::Bool(true)), "{bare}");
    }

    #[test]
    fn sweep_accepts_models_axis() {
        let r = handle(
            r#"{"cmd": "sweep", "benchmarks": ["vector_addition"],
                "models": ["vecchain"], "profiles": ["test"],
                "modes": ["vector"], "lanes": [2], "vlens": [256],
                "threads": 1}"#,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        let points = r.get("points").unwrap().as_arr().unwrap();
        assert_eq!(points.len(), 2);
        assert_eq!(
            points[0].get("benchmark").unwrap().as_str(),
            Some("vector_addition")
        );
        assert_eq!(
            points[1].get("benchmark").unwrap().as_str(),
            Some("model:vecchain")
        );
        assert!(points[1].get("stages").unwrap().as_arr().unwrap().len() > 0);
        // Kernel rows carry no stages field at all.
        assert_eq!(points[0].get("stages"), None);
    }

    #[test]
    fn list_advertises_models() {
        let r = handle(r#"{"cmd": "list"}"#);
        let names: Vec<&str> = r
            .get("models")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|m| m.as_str().unwrap())
            .collect();
        assert_eq!(names, vec!["tinycnn", "mlp", "vecchain"]);
    }

    #[test]
    fn unknown_profile_rejected() {
        let r = handle(
            r#"{"cmd": "bench", "benchmark": "vector_addition",
                "profile": "enormous"}"#,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(r.get("error").unwrap().as_str(), Some("unknown profile"));
    }

    #[test]
    fn unknown_describe_figure_rejected() {
        let r = handle(r#"{"cmd": "describe", "what": "flux-capacitor"}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn sweep_roundtrip_with_cache() {
        let r = handle(
            r#"{"cmd": "sweep", "benchmarks": ["vector_addition"],
                "profiles": ["test"], "modes": ["vector"],
                "lanes": [1, 2, 2], "vlens": [256], "threads": 2}"#,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        let points = r.get("points").unwrap().as_arr().unwrap();
        assert_eq!(points.len(), 3);
        for p in points {
            assert_eq!(p.get("ok"), Some(&Json::Bool(true)));
            assert_eq!(p.get("verified"), Some(&Json::Bool(true)));
            assert!(p.get("cycles").unwrap().as_u64().unwrap() > 0);
        }
        // lanes [1, 2, 2]: one duplicated point answered from the cache.
        assert_eq!(r.get("unique_simulated").unwrap().as_u64(), Some(2));
        assert_eq!(r.get("cache_hits").unwrap().as_u64(), Some(1));
        // The two unique lane variants share a cohort and ran lockstep.
        assert_eq!(r.get("batched_points").unwrap().as_u64(), Some(2));
        assert_eq!(r.get("batch_groups").unwrap().as_u64(), Some(1));
        // Duplicated points carry byte-identical results.
        assert_eq!(points[1].to_string(), points[2].to_string());
    }

    #[test]
    fn batch_reuses_one_evaluator() {
        let evaluator = Evaluator::new();
        let body = r#"{"cmd": "batch", "requests": [
            {"cmd": "ping"},
            {"cmd": "bench", "benchmark": "vector_addition",
             "profile": "test", "mode": "vector", "lanes": 1},
            {"cmd": "bench", "benchmark": "vector_addition",
             "profile": "test", "mode": "vector", "lanes": 2},
            {"cmd": "bench", "benchmark": "bogus", "profile": "test"}
        ]}"#;
        let r = handle_request(&req(body), &evaluator);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        assert_eq!(r.get("count").unwrap().as_u64(), Some(4));
        let responses = r.get("responses").unwrap().as_arr().unwrap();
        assert_eq!(responses[0].get("pong"), Some(&Json::Bool(true)));
        for resp in &responses[1..3] {
            assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
            assert_eq!(resp.get("verified"), Some(&Json::Bool(true)));
        }
        // A failing sub-request fails alone, not the envelope.
        assert_eq!(responses[3].get("ok"), Some(&Json::Bool(false)));
        // Both bench points share one (benchmark, mode, size) program.
        assert_eq!(evaluator.programs().len(), 1);
    }

    #[test]
    fn batch_shape_and_nesting_rejected() {
        for body in [
            r#"{"cmd": "batch"}"#,
            r#"{"cmd": "batch", "requests": "ping"}"#,
        ] {
            let r = handle(body);
            assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "{body}");
        }
        let r = handle(
            r#"{"cmd": "batch", "requests":
                [{"cmd": "batch", "requests": []}]}"#,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        let responses = r.get("responses").unwrap().as_arr().unwrap();
        assert_eq!(responses[0].get("ok"), Some(&Json::Bool(false)));
        assert!(responses[0]
            .get("error")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("nested"));
    }

    #[test]
    fn batch_size_limit_enforced() {
        let pings: Vec<&str> = (0..257).map(|_| r#"{"cmd":"ping"}"#).collect();
        let body = format!(
            r#"{{"cmd": "batch", "requests": [{}]}}"#,
            pings.join(",")
        );
        let r = handle(&body);
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        assert!(r
            .get("error")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("limit"));
    }

    #[test]
    fn sweep_spans_elen_and_timing_axes() {
        let r = handle(
            r#"{"cmd": "sweep", "benchmarks": ["vector_addition"],
                "profiles": ["test"], "modes": ["vector"],
                "lanes": [2], "vlens": [256], "elens": [32, 64],
                "timing": ["baseline", "burst-mem"], "threads": 2}"#,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        let points = r.get("points").unwrap().as_arr().unwrap();
        assert_eq!(points.len(), 4);
        // Every combination is a distinct, simulated design point.
        assert_eq!(r.get("unique_simulated").unwrap().as_u64(), Some(4));
        assert_eq!(r.get("cache_hits").unwrap().as_u64(), Some(0));
        let mut keys: Vec<&str> = points
            .iter()
            .map(|p| p.get("key").unwrap().as_str().unwrap())
            .collect();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), 4);
        // Per-point axis fields ride the report.
        assert_eq!(points[0].get("elen").unwrap().as_u64(), Some(32));
        assert_eq!(
            points[0].get("timing").unwrap().as_str(),
            Some("baseline")
        );
        assert_eq!(
            points[1].get("timing").unwrap().as_str(),
            Some("burst-mem")
        );
    }

    #[test]
    fn shard_handshake_surfaces_ledger_stats() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static NEXT: AtomicUsize = AtomicUsize::new(0);
        let dir = std::env::temp_dir().join(format!(
            "arrow-server-ledger-{}-{}",
            std::process::id(),
            NEXT.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let evaluator = Evaluator::with_store_dir(&dir).unwrap();
        // Populate the ledger through a real evaluation.
        let r = handle_request(
            &req(r#"{"cmd": "bench", "benchmark": "vector_addition",
                     "profile": "test", "mode": "vector"}"#),
            &evaluator,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        let shard = handle_request(&req(r#"{"cmd": "shard"}"#), &evaluator);
        assert_eq!(shard.get("store"), Some(&Json::Bool(true)));
        let ledger = shard.get("ledger").unwrap();
        assert_eq!(ledger.get("entries").unwrap().as_u64(), Some(1));
        assert!(ledger.get("bytes").unwrap().as_u64().unwrap() > 0);
        assert_eq!(ledger.get("superseded").unwrap().as_u64(), Some(0));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sweep_response_reports_measured_wall_time() {
        let stats = ServerStats::default();
        let r = handle_request_with(
            &req(r#"{"cmd": "sweep", "benchmarks": ["vector_addition"],
                     "profiles": ["test"], "modes": ["vector"],
                     "lanes": [2], "vlens": [256], "threads": 1}"#),
            &Evaluator::new(),
            &stats,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        // Real work took measurable time, and the shard counter moved.
        assert!(r.get("elapsed_ms").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(stats.sweeps_served.load(Ordering::Relaxed), 1);
        // The point rows carry the energy axis.
        let p = &r.get("points").unwrap().as_arr().unwrap()[0];
        let energy = p.get("energy").unwrap();
        assert!(energy.get("joules").unwrap().as_f64().unwrap() > 0.0);
        assert!(r.get("energy_total_j").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn shard_handshake_surfaces_load() {
        let stats = ServerStats::default();
        stats.in_flight.store(3, Ordering::Relaxed);
        stats.sweeps_served.store(7, Ordering::Relaxed);
        let r = handle_request_with(
            &req(r#"{"cmd": "shard"}"#),
            &Evaluator::new(),
            &stats,
        );
        let load = r.get("load").unwrap();
        assert_eq!(load.get("in_flight").unwrap().as_u64(), Some(3));
        assert_eq!(load.get("sweeps_served").unwrap().as_u64(), Some(7));
    }

    #[test]
    fn register_payload_carries_identity_load_and_ledger() {
        let stats = ServerStats::default();
        stats.sweeps_served.store(5, Ordering::Relaxed);
        let p = register_payload("10.1.1.1:7", &Evaluator::new(), &stats);
        assert_eq!(p.get("cmd").unwrap().as_str(), Some("register"));
        assert_eq!(p.get("addr").unwrap().as_str(), Some("10.1.1.1:7"));
        assert_eq!(
            p.get("version").unwrap().as_str(),
            Some(env!("CARGO_PKG_VERSION"))
        );
        assert_eq!(
            p.get("max_grid").unwrap().as_u64(),
            Some(MAX_SWEEP_GRID as u64)
        );
        assert_eq!(
            p.get("load").unwrap().get("sweeps_served").unwrap().as_u64(),
            Some(5)
        );
        // Storeless workers advertise no ledger.
        assert_eq!(p.get("ledger"), None);
    }

    #[test]
    fn list_advertises_timing_variants() {
        let r = handle(r#"{"cmd": "list"}"#);
        let names: Vec<&str> = r
            .get("timing_variants")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_str().unwrap())
            .collect();
        assert_eq!(names, vec!["baseline", "fast-dispatch", "burst-mem"]);
    }

    #[test]
    fn sweep_invalid_lane_count_reported_per_point() {
        let r = handle(
            r#"{"cmd": "sweep", "benchmarks": ["vector_addition"],
                "profiles": ["test"], "modes": ["vector"],
                "lanes": [3], "vlens": [256], "threads": 1}"#,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        let points = r.get("points").unwrap().as_arr().unwrap();
        assert_eq!(points[0].get("ok"), Some(&Json::Bool(false)));
        assert!(points[0]
            .get("error")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("lanes"));
    }

    #[test]
    fn sweep_bad_shapes_rejected() {
        for body in [
            r#"{"cmd": "sweep", "benchmarks": ["sudoku"]}"#,
            r#"{"cmd": "sweep", "profiles": ["galactic"]}"#,
            r#"{"cmd": "sweep", "modes": ["quantum"]}"#,
            r#"{"cmd": "sweep", "benchmarks": "vector_addition"}"#,
            r#"{"cmd": "sweep", "lanes": ["two"]}"#,
            r#"{"cmd": "sweep", "vlens": []}"#,
            r#"{"cmd": "sweep", "elens": ["wide"]}"#,
            // 2^32 + 32 must be rejected, not truncated onto ELEN 32.
            r#"{"cmd": "sweep", "elens": [4294967328]}"#,
            r#"{"cmd": "sweep", "vlens": [4294967552]}"#,
            r#"{"cmd": "sweep", "timing": ["warp-drive"]}"#,
            r#"{"cmd": "sweep", "timing": []}"#,
        ] {
            let r = handle(body);
            assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "{body}");
        }
    }

    #[test]
    fn sweep_grid_limit_enforced() {
        // 9 benchmarks x 4 profiles x 2 modes x 6 lane counts x 5 VLENs
        // = 2160 would run for hours on the large profile; the limit is
        // on the *count*, so trip it with repeated entries instead.
        let lanes: Vec<String> =
            (0..5000).map(|_| "2".to_string()).collect();
        let body = format!(
            r#"{{"cmd": "sweep", "benchmarks": ["vector_addition"],
                 "profiles": ["test"], "modes": ["vector"],
                 "lanes": [{}], "vlens": [256]}}"#,
            lanes.join(",")
        );
        let r = handle(&body);
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        assert!(r
            .get("error")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("limit"));
    }

    #[test]
    fn describe_over_protocol() {
        let r = handle(
            r#"{"cmd": "describe", "what": "system", "lanes": 4}"#,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert!(r.get("text").unwrap().as_str().unwrap().contains("DDR3"));
    }

    #[test]
    fn bad_config_rejected() {
        let r = handle(
            r#"{"cmd": "bench", "benchmark": "vector_relu",
                "profile": "test", "lanes": 3}"#,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn end_to_end_over_tcp() {
        use std::io::{BufRead, BufReader};
        let core = Arc::new(ServerCore::new(
            Evaluator::new(),
            ExecutorOptions { workers: 2, queue_depth: 8 },
        ));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let poller_core = Arc::clone(&core);
        let poller =
            std::thread::spawn(move || run_poller(listener, &poller_core));
        let mut client = TcpStream::connect(addr).unwrap();
        writeln!(client, r#"{{"cmd": "ping"}}"#).unwrap();
        let mut line = String::new();
        BufReader::new(client.try_clone().unwrap())
            .read_line(&mut line)
            .unwrap();
        let resp = json::parse(line.trim()).unwrap();
        assert_eq!(resp.get("pong"), Some(&Json::Bool(true)));
        // Graceful stop: the drain flag winds the poller down, the
        // executor drains, and the poller thread returns.
        core.shutdown.store(true, Ordering::Release);
        poller.join().unwrap().unwrap();
        assert_eq!(core.stats.served.load(Ordering::Relaxed), 1);
        assert_eq!(core.stats.poller_fds.load(Ordering::Relaxed), 0);
    }

    /// The write-queue overflow path: a connection whose queued output
    /// exceeds [`WRITE_QUEUE_CAP`] answers structured `busy` for
    /// further requests instead of buffering more response bytes.
    #[test]
    fn write_queue_overflow_answers_busy() {
        let core = Arc::new(ServerCore::new(
            Evaluator::new(),
            ExecutorOptions { workers: 1, queue_depth: 8 },
        ));
        let waker = Arc::new(Waker::new().unwrap());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let client = TcpStream::connect(listener.local_addr().unwrap())
            .unwrap();
        let (stream, peer) = listener.accept().unwrap();
        stream.set_nonblocking(true).unwrap();
        let mut conn = Conn {
            stream,
            peer: Some(peer),
            rbuf: Vec::new(),
            seq: 0,
            shared: Arc::new(ConnShared::new(waker)),
            closed_read: false,
        };
        // Pre-fill the write queue past the cap, as a slow reader
        // would.
        lock_out(&conn.shared.out).wbuf = vec![b'x'; WRITE_QUEUE_CAP + 1];
        process_line(&core, &mut conn, r#"{"cmd": "ping", "id": 3}"#);
        let o = lock_out(&conn.shared.out);
        let tail =
            String::from_utf8_lossy(&o.wbuf[WRITE_QUEUE_CAP + 1..])
                .into_owned();
        drop(o);
        let resp = json::parse(tail.trim()).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(resp.get("busy"), Some(&Json::Bool(true)));
        assert_eq!(resp.get("id").unwrap().as_u64(), Some(3));
        assert!(resp
            .get("error")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("write queue"));
        assert_eq!(core.stats.rejected.load(Ordering::Relaxed), 1);
        drop(client);
    }

    #[test]
    fn stats_command_reports_counters_latency_and_pools() {
        let evaluator = Evaluator::new();
        let stats = ServerStats::default();
        // One completed request on the books.
        stats.record(kind_of(Some("ping")), Duration::from_micros(250));
        stats.queue_depth.store(3, Ordering::Relaxed);
        stats.rejected.store(2, Ordering::Relaxed);
        let r = handle_request_with(
            &req(r#"{"cmd": "stats"}"#),
            &evaluator,
            &stats,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        assert_eq!(r.get("served").unwrap().as_u64(), Some(1));
        assert_eq!(r.get("rejected").unwrap().as_u64(), Some(2));
        assert_eq!(r.get("queue_depth").unwrap().as_u64(), Some(3));
        let lat = r.get("latency_us").unwrap();
        let all = lat.get("all").unwrap();
        assert_eq!(all.get("count").unwrap().as_u64(), Some(1));
        assert!(all.get("p99_us").unwrap().as_u64().unwrap() >= 250);
        // The ping histogram has samples, so it is reported; bench has
        // none, so it is omitted.
        assert!(lat.get("ping").is_some());
        assert!(lat.get("bench").is_none());
        let sessions = r.get("sessions").unwrap();
        assert_eq!(sessions.get("pooled").unwrap().as_u64(), Some(0));
        // The interval window drains on read: first stats call sees the
        // recorded sample, the next sees an empty window.
        let w = r.get("latency_window_us").unwrap();
        assert_eq!(w.get("count").unwrap().as_u64(), Some(1));
        let r2 = handle_request_with(
            &req(r#"{"cmd": "stats"}"#),
            &evaluator,
            &stats,
        );
        let w2 = r2.get("latency_window_us").unwrap();
        assert_eq!(w2.get("count").unwrap().as_u64(), Some(0));
        // The since-startup aggregate is untouched by window drains.
        let all2 = r2.get("latency_us").unwrap().get("all").unwrap();
        assert_eq!(all2.get("count").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn metrics_command_renders_prometheus_text() {
        let evaluator = Evaluator::new();
        let stats = ServerStats::default();
        stats.record(kind_of(Some("sweep")), Duration::from_micros(900));
        let r = handle_request_with(
            &req(r#"{"cmd": "metrics"}"#),
            &evaluator,
            &stats,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        assert_eq!(
            r.get("content_type").unwrap().as_str(),
            Some("text/plain; version=0.0.4")
        );
        let body = r.get("body").unwrap().as_str().unwrap();
        assert!(body.contains("# TYPE arrow_requests_served_total counter"));
        assert!(body.contains("arrow_requests_served_total 1"));
        assert!(body.contains("# TYPE arrow_request_latency_us summary"));
        assert!(body
            .contains("arrow_request_latency_us{kind=\"sweep\",quantile="));
        assert!(body.contains("arrow_eval_simulated_total"));
        // Every non-comment line is `name[{labels}] value` — the shape a
        // Prometheus text parser accepts.
        for line in body.lines() {
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (name, value) =
                line.rsplit_once(' ').expect("sample line");
            assert!(!name.is_empty(), "{line}");
            assert!(value.parse::<f64>().is_ok(), "{line}");
        }
    }

    #[test]
    fn warm_command_populates_session_pool() {
        let evaluator = Evaluator::new();
        let r = handle_request(
            &req(r#"{"cmd": "warm", "benchmarks": ["vector_addition"],
                     "profiles": ["test"], "modes": ["vector"],
                     "lanes": [1, 2], "vlens": [256]}"#),
            &evaluator,
        );
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        assert_eq!(r.get("warmed").unwrap().as_u64(), Some(2));
        assert_eq!(r.get("errors").unwrap().as_u64(), Some(0));
        assert_eq!(evaluator.sessions().len(), 2);
        // The first real evaluation of a warmed point is a pool hit.
        let b = handle_request(
            &req(r#"{"cmd": "bench", "benchmark": "vector_addition",
                     "profile": "test", "mode": "vector", "lanes": 2}"#),
            &evaluator,
        );
        assert_eq!(b.get("ok"), Some(&Json::Bool(true)), "{b}");
        assert_eq!(evaluator.sessions().hits(), 1);
        // Bad axes are request errors, same contract as sweep.
        let bad = handle_request(
            &req(r#"{"cmd": "warm", "benchmarks": ["sudoku"]}"#),
            &evaluator,
        );
        assert_eq!(bad.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn sleep_command_sleeps_and_is_capped() {
        let started = Instant::now();
        let r = handle(r#"{"cmd": "sleep", "ms": 30}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(r.get("slept_ms").unwrap().as_u64(), Some(30));
        assert!(started.elapsed() >= Duration::from_millis(30));
        // The cap defangs hostile sleeps without erroring.
        let r = handle(r#"{"cmd": "sleep", "ms": 86400000}"#);
        assert_eq!(
            r.get("slept_ms").unwrap().as_u64(),
            Some(MAX_SLEEP_MS)
        );
    }

    /// Regression test for the `in_flight` leak: a panicking handler
    /// must still decrement the gauge (the drop guard runs during
    /// unwind), so heartbeats never report phantom load forever.
    #[test]
    fn in_flight_guard_releases_on_panic() {
        let stats = ServerStats::default();
        let result =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let _guard = InFlightGuard::new(&stats);
                assert_eq!(stats.in_flight.load(Ordering::Relaxed), 1);
                panic!("injected handler panic");
            }));
        assert!(result.is_err());
        assert_eq!(stats.in_flight.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn shutdown_rejected_outside_connection_layer() {
        // Pure handler (and therefore batch envelopes): refused.
        let r = handle(r#"{"cmd": "shutdown"}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        let r = handle(
            r#"{"cmd": "batch", "requests": [{"cmd": "shutdown"}]}"#,
        );
        let sub = &r.get("responses").unwrap().as_arr().unwrap()[0];
        assert_eq!(sub.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn attach_id_echoes_any_json_value() {
        let resp = Json::obj(vec![("ok", true.into())]);
        let tagged = attach_id(resp.clone(), Some(Json::Str("a7".into())));
        assert_eq!(tagged.get("id").unwrap().as_str(), Some("a7"));
        let numeric = attach_id(resp.clone(), Some(7u64.into()));
        assert_eq!(numeric.get("id").unwrap().as_u64(), Some(7));
        assert_eq!(attach_id(resp, None).get("id"), None);
    }

    #[test]
    fn busy_response_is_structured() {
        let r = busy_response(&Reject::QueueFull { depth: 9 });
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(r.get("busy"), Some(&Json::Bool(true)));
        assert!(r.get("error").unwrap().as_str().unwrap().contains("busy"));
    }

    #[test]
    fn load_json_carries_saturation_signals() {
        let stats = ServerStats::default();
        stats.queue_depth.store(5, Ordering::Relaxed);
        stats.rejected.store(11, Ordering::Relaxed);
        stats.record(0, Duration::from_micros(10));
        let l = stats.load_json();
        assert_eq!(l.get("queue_depth").unwrap().as_u64(), Some(5));
        assert_eq!(l.get("rejected").unwrap().as_u64(), Some(11));
        assert_eq!(l.get("served").unwrap().as_u64(), Some(1));
    }
}
