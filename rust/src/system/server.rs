//! Job server: the simulator as a service.
//!
//! Line-delimited JSON over TCP, one thread per connection (the build is
//! offline so there is no async runtime; the protocol and handlers are
//! runtime-agnostic).  Requests:
//!
//! ```json
//! {"cmd": "ping"}
//! {"cmd": "bench", "benchmark": "vector_addition", "profile": "small",
//!  "mode": "vector", "lanes": 2}
//! {"cmd": "describe", "what": "datapath"}
//! {"cmd": "list"}
//! ```
//!
//! Responses are single-line JSON with `"ok": true/false`.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use crate::bench::runner::{run_benchmark, Mode};
use crate::bench::suite::{Benchmark, BENCHMARKS};
use crate::bench::Profile;
use crate::util::json::{self, Json};
use crate::vector::ArrowConfig;

use super::describe;

fn err_response(msg: impl Into<String>) -> Json {
    Json::obj(vec![("ok", false.into()), ("error", Json::Str(msg.into()))])
}

/// Handle one request object (pure; exercised directly by tests).
pub fn handle_request(req: &Json) -> Json {
    match req.get("cmd").and_then(Json::as_str) {
        Some("ping") => {
            Json::obj(vec![("ok", true.into()), ("pong", true.into())])
        }
        Some("list") => Json::obj(vec![
            ("ok", true.into()),
            (
                "benchmarks",
                Json::Arr(
                    BENCHMARKS.iter().map(|b| b.name().into()).collect(),
                ),
            ),
            (
                "profiles",
                Json::Arr(
                    ["small", "medium", "large", "test"]
                        .iter()
                        .map(|&p| p.into())
                        .collect(),
                ),
            ),
        ]),
        Some("describe") => {
            let c = config_from(req);
            let what =
                req.get("what").and_then(Json::as_str).unwrap_or("datapath");
            let text = match what {
                "datapath" => describe::datapath(&c),
                "write-enable" => describe::write_enable(&c),
                "simd-alu" => describe::simd_alu(&c),
                "system" => describe::system(&c),
                other => {
                    return err_response(format!(
                        "unknown description `{other}`"
                    ))
                }
            };
            Json::obj(vec![("ok", true.into()), ("text", text.into())])
        }
        Some("bench") => {
            let Some(b) = req
                .get("benchmark")
                .and_then(Json::as_str)
                .and_then(Benchmark::by_name)
            else {
                return err_response("unknown benchmark");
            };
            let Some(p) = req
                .get("profile")
                .and_then(Json::as_str)
                .and_then(Profile::by_name)
            else {
                return err_response("unknown profile");
            };
            let mode = match req.get("mode").and_then(Json::as_str) {
                Some("scalar") => Mode::Scalar,
                _ => Mode::Vector,
            };
            let config = config_from(req);
            if let Err(e) = config.validate() {
                return err_response(e);
            }
            let size = b.size(&p);
            match run_benchmark(b, size, mode, config, 42) {
                Ok(r) => Json::obj(vec![
                    ("ok", true.into()),
                    ("benchmark", b.name().into()),
                    ("mode", mode.name().into()),
                    ("cycles", r.cycles.into()),
                    ("verified", r.verified.into()),
                    (
                        "scalar_instructions",
                        r.summary.scalar_instructions.into(),
                    ),
                    (
                        "vector_instructions",
                        r.summary.vector_instructions.into(),
                    ),
                ]),
                Err(e) => err_response(e.to_string()),
            }
        }
        other => err_response(format!(
            "unknown cmd {other:?} (ping|list|bench|describe)"
        )),
    }
}

fn config_from(req: &Json) -> ArrowConfig {
    let mut c = ArrowConfig::default();
    if let Some(lanes) = req.get("lanes").and_then(Json::as_u64) {
        c.lanes = lanes as usize;
    }
    if let Some(vlen) = req.get("vlen").and_then(Json::as_u64) {
        c.vlen_bits = vlen as u32;
    }
    c
}

fn handle_conn(stream: TcpStream) {
    let peer = stream.peer_addr().ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let response = match json::parse(&line) {
            Ok(req) => handle_request(&req),
            Err(e) => err_response(format!("bad json: {e}")),
        };
        if writeln!(writer, "{response}").is_err() {
            break;
        }
    }
    if let Some(peer) = peer {
        eprintln!("connection from {peer} closed");
    }
}

/// Serve forever on `addr` (e.g. `127.0.0.1:7676`), one thread per
/// connection.
pub fn serve(addr: &str) -> std::io::Result<()> {
    let listener = TcpListener::bind(addr)?;
    eprintln!("arrow simulator serving on {addr}");
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                std::thread::spawn(move || handle_conn(s));
            }
            Err(e) => eprintln!("accept: {e}"),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(s: &str) -> Json {
        json::parse(s).unwrap()
    }

    #[test]
    fn ping() {
        let r = handle_request(&req(r#"{"cmd": "ping"}"#));
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    }

    #[test]
    fn bench_roundtrip() {
        let r = handle_request(&req(
            r#"{"cmd": "bench", "benchmark": "vector_addition",
                "profile": "test", "mode": "vector"}"#,
        ));
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        assert_eq!(r.get("verified"), Some(&Json::Bool(true)));
        assert!(r.get("cycles").unwrap().as_u64().unwrap() > 0);
    }

    #[test]
    fn unknown_cmd_rejected() {
        let r = handle_request(&req(r#"{"cmd": "nuke"}"#));
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn describe_over_protocol() {
        let r = handle_request(&req(
            r#"{"cmd": "describe", "what": "system", "lanes": 4}"#,
        ));
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert!(r.get("text").unwrap().as_str().unwrap().contains("DDR3"));
    }

    #[test]
    fn bad_config_rejected() {
        let r = handle_request(&req(
            r#"{"cmd": "bench", "benchmark": "vector_relu",
                "profile": "test", "lanes": 3}"#,
        ));
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn end_to_end_over_tcp() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            handle_conn(s);
        });
        let mut client = TcpStream::connect(addr).unwrap();
        writeln!(client, r#"{{"cmd": "ping"}}"#).unwrap();
        let mut line = String::new();
        BufReader::new(client.try_clone().unwrap())
            .read_line(&mut line)
            .unwrap();
        let resp = json::parse(line.trim()).unwrap();
        assert_eq!(resp.get("pong"), Some(&Json::Bool(true)));
    }
}
