//! Persistent-store integration: a repeated `arrow sweep` with a cache
//! directory must answer entirely from the store (zero simulated
//! points), byte-identically to the first run — and a vandalised store
//! must degrade to re-simulation, never a panic.

use std::fs::OpenOptions;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};

use arrow_rvv::bench::profiles;
use arrow_rvv::bench::runner::Mode;
use arrow_rvv::bench::store::STORE_FILE;
use arrow_rvv::bench::suite::Benchmark;
use arrow_rvv::bench::sweep::{run_sweep, Provenance, SweepSpec};

fn tmp_dir(tag: &str) -> PathBuf {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    let dir = std::env::temp_dir().join(format!(
        "arrow-evaluator-store-{tag}-{}-{}",
        std::process::id(),
        NEXT.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn cached_spec(dir: &Path) -> SweepSpec {
    SweepSpec {
        benchmarks: vec![Benchmark::VAdd, Benchmark::VDot],
        profiles: vec![profiles::TEST],
        modes: vec![Mode::Scalar, Mode::Vector],
        lanes: vec![1, 2],
        vlens: vec![256],
        seed: 42,
        threads: 2,
        cache_dir: Some(dir.to_path_buf()),
        ..Default::default()
    }
}

/// The acceptance criterion: run twice against one cache directory; the
/// second run simulates nothing and reproduces the first run exactly.
#[test]
fn repeated_sweep_answers_entirely_from_the_store() {
    let dir = tmp_dir("roundtrip");
    let spec = cached_spec(&dir);

    let first = run_sweep(&spec);
    assert!(first.store_error.is_none(), "{:?}", first.store_error);
    assert_eq!(first.unique_simulated, spec.grid_len());
    assert_eq!(first.store_hits, 0);
    assert!(dir.join(STORE_FILE).exists());

    let second = run_sweep(&spec);
    assert_eq!(second.unique_simulated, 0, "second run must not simulate");
    assert_eq!(second.analytic, 0);
    assert_eq!(second.store_hits, spec.grid_len());

    assert_eq!(first.points.len(), second.points.len());
    for (a, b) in first.points.iter().zip(&second.points) {
        assert_eq!(a.key, b.key);
        let fresh = a.outcome.as_ref().unwrap();
        let cached = b.outcome.as_ref().unwrap();
        assert_eq!(fresh.provenance, Provenance::Simulated, "{}", a.key);
        assert_eq!(cached.provenance, Provenance::Cached, "{}", b.key);
        assert_eq!(cached.origin, Provenance::Simulated, "{}", b.key);
        // Identical modulo provenance: the store reproduced the full
        // ledger, not just the headline cycle count.
        assert_eq!(fresh.cycles, cached.cycles, "{}", a.key);
        assert_eq!(fresh.verified, cached.verified, "{}", a.key);
        assert_eq!(fresh.summary, cached.summary, "{}", a.key);
    }

    // A different seed misses the store entirely (the canonical key
    // folds the seed in) and simulates afresh.
    let reseeded = SweepSpec { seed: 43, ..cached_spec(&dir) };
    let third = run_sweep(&reseeded);
    assert_eq!(third.unique_simulated, reseeded.grid_len());
    assert_eq!(third.store_hits, 0);

    std::fs::remove_dir_all(&dir).unwrap();
}

/// Analytic estimates persist too: a second sweep at the same grid
/// serves yesterday's extrapolations from disk.
#[test]
fn analytic_results_are_stored_and_replayed() {
    let dir = tmp_dir("analytic");
    let spec = SweepSpec {
        benchmarks: vec![Benchmark::VAdd],
        profiles: vec![profiles::TEST],
        modes: vec![Mode::Vector],
        lanes: vec![2],
        vlens: vec![256],
        seed: 1,
        threads: 1,
        analytic_limit: Some(0),
        cache_dir: Some(dir.clone()),
        ..Default::default()
    };
    let first = run_sweep(&spec);
    assert_eq!(first.analytic, 1);
    let second = run_sweep(&spec);
    assert_eq!(second.analytic, 0);
    assert_eq!(second.store_hits, 1);
    let replayed = second.points[0].outcome.as_ref().unwrap();
    // Replayed estimates keep their origin: a consumer can always tell
    // an extrapolation from an exact measurement.
    assert_eq!(replayed.origin, Provenance::Analytic);
    assert_eq!(
        first.points[0].outcome.as_ref().unwrap().cycles,
        replayed.cycles
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

/// A multi-precision grid (two ELENs × all three timing variants)
/// lands one distinct store record per point, and a repeated sweep
/// replays every one of them — the ablations can never cross-talk
/// through the cache.
#[test]
fn elen_timing_axes_get_distinct_store_records() {
    let dir = tmp_dir("axes");
    let spec = SweepSpec {
        benchmarks: vec![Benchmark::VAdd],
        profiles: vec![profiles::TEST],
        modes: vec![Mode::Vector],
        lanes: vec![2],
        vlens: vec![256],
        elens: vec![32, 64],
        timing: profiles::TIMING_VARIANTS.to_vec(),
        seed: 7,
        threads: 2,
        cache_dir: Some(dir.clone()),
        ..Default::default()
    };
    let first = run_sweep(&spec);
    assert_eq!(first.unique_simulated, 6, "six distinct design points");
    // Six distinct records on disk: one JSON line per point.
    let ledger = std::fs::read_to_string(dir.join(STORE_FILE)).unwrap();
    assert_eq!(ledger.lines().count(), 6);

    let second = run_sweep(&spec);
    assert_eq!(second.unique_simulated, 0);
    assert_eq!(second.store_hits, 6);
    for (a, b) in first.points.iter().zip(&second.points) {
        assert_eq!(a.key, b.key);
        let (fresh, cached) =
            (a.outcome.as_ref().unwrap(), b.outcome.as_ref().unwrap());
        assert_eq!(cached.provenance, Provenance::Cached, "{}", b.key);
        assert_eq!(fresh.cycles, cached.cycles, "{}", a.key);
        assert_eq!(fresh.summary, cached.summary, "{}", a.key);
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Truncating and vandalising the ledger between runs degrades cleanly:
/// unreadable records re-simulate, the rest of the sweep still answers,
/// and nothing panics.
#[test]
fn corrupt_store_degrades_to_resimulation() {
    let dir = tmp_dir("corrupt");
    let spec = cached_spec(&dir);
    let first = run_sweep(&spec);
    assert_eq!(first.unique_simulated, spec.grid_len());

    // Chop the last line in half and append garbage.
    let path = dir.join(STORE_FILE);
    let ledger = std::fs::read_to_string(&path).unwrap();
    let truncated = &ledger[..ledger.len() - ledger.len() / 4];
    std::fs::write(&path, truncated).unwrap();
    let mut file = OpenOptions::new().append(true).open(&path).unwrap();
    writeln!(file).unwrap();
    writeln!(file, "}}}}not json{{{{").unwrap();
    drop(file);

    let second = run_sweep(&spec);
    assert!(second.store_error.is_none());
    assert_eq!(second.points.len(), spec.grid_len());
    // Intact records still hit; mangled ones re-simulate — and the
    // results agree with the first run either way.
    assert!(second.unique_simulated > 0, "truncation lost some records");
    assert!(second.store_hits > 0, "intact prefix must still serve");
    assert_eq!(
        second.unique_simulated + second.store_hits,
        spec.grid_len()
    );
    for (a, b) in first.points.iter().zip(&second.points) {
        let fresh = a.outcome.as_ref().unwrap();
        let replayed = b.outcome.as_ref().unwrap();
        assert_eq!(fresh.cycles, replayed.cycles, "{}", a.key);
        assert_eq!(fresh.summary, replayed.summary, "{}", a.key);
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

/// An unopenable cache directory is reported, not fatal: the sweep
/// degrades to uncached evaluation.
#[test]
fn unopenable_store_reports_and_degrades() {
    let dir = tmp_dir("unopenable");
    std::fs::create_dir_all(&dir).unwrap();
    // A *file* where the store expects a directory component.
    let blocker = dir.join("blocker");
    std::fs::write(&blocker, b"not a directory").unwrap();
    let spec = SweepSpec {
        benchmarks: vec![Benchmark::VAdd],
        profiles: vec![profiles::TEST],
        modes: vec![Mode::Vector],
        lanes: vec![2],
        vlens: vec![256],
        seed: 1,
        threads: 1,
        cache_dir: Some(blocker.join("store")),
        ..Default::default()
    };
    let report = run_sweep(&spec);
    assert!(report.store_error.is_some());
    assert_eq!(report.unique_simulated, 1);
    assert!(report.points[0].outcome.is_ok());
    std::fs::remove_dir_all(&dir).unwrap();
}
