//! Flight-recorder integration tests: concurrent sink validity, span
//! nesting, trace-report rendering, and the per-point cycle-attribution
//! exact-sum invariant.
//!
//! The recorder sink is process-global, so every test that enables it
//! — or runs machinery that would record into an enabled sink (sweeps
//! emit evaluator events) — serialises on one lock.

use std::sync::{Mutex, MutexGuard, PoisonError};

use arrow_rvv::bench::runner::Mode;
use arrow_rvv::bench::suite::Benchmark;
use arrow_rvv::bench::sweep::{run_sweep, SweepSpec};
use arrow_rvv::obs::trace::{self, Arg};
use arrow_rvv::util::json::{self, Json};

static RECORDER: Mutex<()> = Mutex::new(());

fn recorder_lock() -> MutexGuard<'static, ()> {
    RECORDER.lock().unwrap_or_else(PoisonError::into_inner)
}

const TRICKY: &str = "quote\" backslash\\ tab\t newline\n";

#[test]
fn concurrent_recorders_emit_valid_jsonl_with_nested_spans() {
    let _guard = recorder_lock();
    let path = std::env::temp_dir()
        .join(format!("arrow_obs_trace_{}.json", std::process::id()));
    trace::enable(&path).unwrap();
    std::thread::scope(|scope| {
        for t in 0..8u64 {
            scope.spawn(move || {
                for i in 0..50u64 {
                    let outer = trace::begin();
                    trace::instant(
                        "test",
                        "probe",
                        &[
                            ("thread", Arg::U64(t)),
                            ("tricky", Arg::Str(TRICKY)),
                        ],
                    );
                    let inner = trace::begin();
                    trace::complete(
                        "test",
                        "inner",
                        inner,
                        &[("i", Arg::U64(i))],
                    );
                    trace::complete(
                        "test",
                        "outer",
                        outer,
                        &[("ok", Arg::Bool(true))],
                    );
                }
            });
        }
    });
    trace::disable();
    let content = std::fs::read_to_string(&path).unwrap();
    let _ = std::fs::remove_file(&path);

    // Every line is one complete JSON event: 8 threads x 50 rounds x
    // 3 events, however the threads raced on the sink.
    let mut events = Vec::new();
    for line in content.lines() {
        let line = line.trim().trim_end_matches(',');
        if line.is_empty() || line == "[" {
            continue;
        }
        events.push(json::parse(line).unwrap_or_else(|e| {
            panic!("torn or invalid trace line `{line}`: {e}")
        }));
    }
    assert_eq!(events.len(), 8 * 50 * 3);

    // String escaping round-trips through the sink.
    let tricky_back = events
        .iter()
        .find_map(|e| e.get("args")?.get("tricky")?.as_str())
        .expect("no probe event with the tricky arg");
    assert_eq!(tricky_back, TRICKY);

    // Span nesting: per thread, the k-th inner span lies within the
    // k-th outer span (each thread emits its events in order, and the
    // sink preserves each thread's subsequence).
    let mut by_tid: std::collections::BTreeMap<u64, (Vec<(u64, u64)>, Vec<(u64, u64)>)> =
        std::collections::BTreeMap::new();
    for e in &events {
        if e.get("ph").and_then(Json::as_str) != Some("X") {
            continue;
        }
        let tid = e.get("tid").and_then(Json::as_u64).unwrap();
        let ts = e.get("ts").and_then(Json::as_u64).unwrap();
        let dur = e.get("dur").and_then(Json::as_u64).unwrap();
        let entry = by_tid.entry(tid).or_default();
        match e.get("name").and_then(Json::as_str) {
            Some("inner") => entry.0.push((ts, dur)),
            Some("outer") => entry.1.push((ts, dur)),
            other => panic!("unexpected X event {other:?}"),
        }
    }
    assert_eq!(by_tid.len(), 8, "expected one tid per thread");
    for (tid, (inners, outers)) in &by_tid {
        assert_eq!(inners.len(), 50, "tid {tid}");
        assert_eq!(outers.len(), 50, "tid {tid}");
        for (k, (&(its, idur), &(ots, odur))) in
            inners.iter().zip(outers).enumerate()
        {
            assert!(
                ots <= its && its + idur <= ots + odur,
                "tid {tid} round {k}: inner [{its}, {}] escapes \
                 outer [{ots}, {}]",
                its + idur,
                ots + odur
            );
        }
    }

    // The offline renderer accepts the real file.
    let report = trace::render_report(&content).unwrap();
    assert!(report.contains("trace: 1200 events"), "{report}");
}

/// Synthetic trace exercising every section of the renderer.
fn synthetic_trace() -> String {
    let lines = [
        r#"{"ph":"i","pid":1,"tid":1,"ts":100,"s":"t","cat":"cluster","name":"shard_carved","args":{"shard":0,"points":8}}"#,
        r#"{"ph":"i","pid":1,"tid":1,"ts":110,"s":"t","cat":"cluster","name":"shard_carved","args":{"shard":1,"points":4}}"#,
        r#"{"ph":"i","pid":1,"tid":2,"ts":120,"s":"t","cat":"fleet","name":"member_joined","args":{"worker":"w1"}}"#,
        r#"{"ph":"X","pid":1,"tid":2,"ts":200,"dur":500,"cat":"cluster","name":"shard_dispatched","args":{"shard":0,"worker":"w1"}}"#,
        r#"{"ph":"i","pid":1,"tid":2,"ts":700,"s":"t","cat":"cluster","name":"shard_merged","args":{"shard":0,"worker":"w1"}}"#,
        r#"{"ph":"X","pid":1,"tid":3,"ts":250,"dur":100,"cat":"cluster","name":"shard_dispatched","args":{"shard":1,"worker":"w2"}}"#,
        r#"{"ph":"i","pid":1,"tid":3,"ts":360,"s":"t","cat":"cluster","name":"shard_requeued","args":{"shard":1}}"#,
        r#"{"ph":"i","pid":1,"tid":1,"ts":400,"s":"t","cat":"fleet","name":"member_failed","args":{"worker":"w2"}}"#,
        r#"{"ph":"i","pid":1,"tid":1,"ts":800,"s":"t","cat":"cluster","name":"shard_fallback","args":{"shard":1}}"#,
        r#"{"ph":"X","pid":1,"tid":4,"ts":210,"dur":40,"cat":"eval","name":"eval","args":{"tier":"simulated","benchmark":"vector_addition"}}"#,
        r#"{"ph":"X","pid":1,"tid":4,"ts":260,"dur":5,"cat":"eval","name":"eval","args":{"tier":"analytic","benchmark":"vector_addition"}}"#,
        r#"{"ph":"i","pid":1,"tid":4,"ts":270,"s":"t","cat":"eval","name":"eval_tier","args":{"tier":"cached","benchmark":"matrix_multiplication"}}"#,
        r#"{"ph":"X","pid":1,"tid":5,"ts":300,"dur":12,"cat":"executor","name":"queue_wait","args":{}}"#,
        r#"{"ph":"X","pid":1,"tid":5,"ts":320,"dur":90,"cat":"executor","name":"queue_wait","args":{}}"#,
        r#"{"ph":"X","pid":1,"tid":6,"ts":400,"dur":30,"cat":"model","name":"model_stage","args":{"model":"vecchain","stage":"add","benchmark":"vector_addition","mode":"vector","cycles":1200,"bytes":2048}}"#,
        r#"{"ph":"X","pid":1,"tid":6,"ts":440,"dur":20,"cat":"model","name":"model_stage","args":{"model":"vecchain","stage":"mul","benchmark":"vector_multiplication","mode":"vector","cycles":900,"bytes":2048}}"#,
        r#"{"ph":"X","pid":1,"tid":6,"ts":470,"dur":25,"cat":"model","name":"model_stage","args":{"model":"vecchain","stage":"add","benchmark":"vector_addition","mode":"vector","cycles":1200,"bytes":2048}}"#,
    ];
    let mut out = String::from("[\n");
    for l in lines {
        out.push_str(l);
        out.push_str(",\n");
    }
    out
}

#[test]
fn render_report_reconstructs_the_shard_lifecycle() {
    let report = trace::render_report(&synthetic_trace()).unwrap();
    assert!(report.contains("trace: 17 events"), "{report}");
    assert!(report.contains("shard lifecycle (2 carved)"), "{report}");
    assert!(
        report.contains(
            "merged: 1  local-fallback: 1  requeues: 1  incomplete: 0"
        ),
        "{report}"
    );
    assert!(report.contains("merged by w1"), "{report}");
    assert!(report.contains("local fallback"), "{report}");
    assert!(!report.contains("INCOMPLETE"), "{report}");
    assert!(report.contains("per-worker shard timeline"), "{report}");
    assert!(report.contains("w1: 1 dispatches"), "{report}");
    assert!(report.contains("evaluator tier mix (3 points)"), "{report}");
    assert!(report.contains("simulated"), "{report}");
    assert!(report.contains("analytic"), "{report}");
    assert!(report.contains("cached"), "{report}");
    assert!(report.contains("executor queue wait (2 requests)"), "{report}");
    assert!(report.contains("fleet membership transitions"), "{report}");
    assert!(report.contains("member_joined"), "{report}");
    assert!(report.contains("member_failed"), "{report}");
    assert!(report.contains("trace horizon"), "{report}");
    // Model layer table: stage order preserved (add before mul), the
    // two `add` spans summed into one row.
    assert!(report.contains("model layers (summed over runs)"), "{report}");
    assert!(report.contains("vecchain   add"), "{report}");
    assert!(report.contains("vecchain   mul"), "{report}");
    assert!(report.contains("2400"), "add cycles not summed: {report}");
    let add_at = report.find("vecchain   add").unwrap();
    let mul_at = report.find("vecchain   mul").unwrap();
    assert!(add_at < mul_at, "stage order lost: {report}");
}

#[test]
fn model_runs_land_per_layer_rows_in_the_trace_report() {
    use arrow_rvv::bench::eval::SessionPool;
    use arrow_rvv::bench::models::ModelId;
    use arrow_rvv::bench::runner::DEFAULT_BUDGET;
    use arrow_rvv::bench::ProgramCache;
    use arrow_rvv::system::ModelSession;
    use arrow_rvv::vector::ArrowConfig;

    let _guard = recorder_lock();
    let path = std::env::temp_dir().join(format!(
        "arrow_obs_trace_model_{}.json",
        std::process::id()
    ));
    trace::enable(&path).unwrap();
    let programs = ProgramCache::new();
    let sessions = SessionPool::default();
    let ms = ModelSession::build(
        ModelId::VecChain,
        Mode::Vector,
        ArrowConfig::default(),
        &programs,
        &sessions,
    )
    .unwrap();
    let run = ms.run(7, DEFAULT_BUDGET).unwrap();
    assert!(run.verified);
    trace::disable();
    let content = std::fs::read_to_string(&path).unwrap();
    let _ = std::fs::remove_file(&path);

    let report = trace::render_report(&content).unwrap();
    assert!(report.contains("model layers"), "{report}");
    for (stage, ledger) in ["add", "mul", "relu"].iter().zip(&run.stages) {
        assert!(
            report.contains(&format!("vecchain   {stage}")),
            "missing layer {stage}: {report}"
        );
        assert!(
            report.contains(&ledger.cycles.to_string()),
            "layer {stage} cycles {} not in report: {report}",
            ledger.cycles
        );
    }
}

#[test]
fn render_report_flags_incomplete_shards_and_torn_input() {
    // A shard that was carved and dispatched but never merged nor fell
    // back is a coordinator bug the report must surface loudly.
    let content = "[\n\
        {\"ph\":\"i\",\"tid\":1,\"ts\":1,\"cat\":\"cluster\",\
         \"name\":\"shard_carved\",\"args\":{\"shard\":0,\"points\":2}},\n\
        {\"ph\":\"X\",\"tid\":1,\"ts\":2,\"dur\":3,\"cat\":\"cluster\",\
         \"name\":\"shard_dispatched\",\"args\":{\"shard\":0,\"worker\":\"w\"}},\n";
    let report = trace::render_report(content).unwrap();
    assert!(report.contains("incomplete: 1"), "{report}");
    assert!(report.contains("INCOMPLETE shard 0"), "{report}");

    // A torn line (interrupted writer) is a hard parse error, not a
    // silently shortened report.
    let torn = "[\n{\"ph\":\"i\",\"tid\":1,\"ts\":1,\"cat\":\"c\",\"na\n";
    let err = trace::render_report(torn).unwrap_err();
    assert!(err.contains("line 2"), "{err}");
}

#[test]
fn sweep_attribution_sums_exactly_to_cycles_across_tiers() {
    let _guard = recorder_lock();
    let vadd = Benchmark::by_name("vector_addition").unwrap();

    // Simulated tier (both modes; lanes 1 and 2 share a cohort, so the
    // lockstep batch path contributes points too).
    let mut spec = SweepSpec {
        benchmarks: vec![vadd],
        modes: vec![Mode::Scalar, Mode::Vector],
        lanes: vec![1, 2],
        vlens: vec![128],
        threads: 1,
        ..Default::default()
    };
    let simulated = run_sweep(&spec);
    assert!(simulated.unique_simulated > 0);

    // Analytic tier: an extrapolated point carries the fit point's
    // attribution scaled to its estimated cycle count — the sum
    // invariant must survive the scaling.
    spec.modes = vec![Mode::Vector];
    spec.analytic_limit = Some(1);
    let analytic = run_sweep(&spec);
    assert!(
        analytic.analytic > 0,
        "analytic_limit 1 produced no analytic points; the scaled \
         attribution path went untested"
    );

    let mut checked = 0usize;
    for p in simulated.points.iter().chain(&analytic.points) {
        let o = p.outcome.as_ref().unwrap_or_else(|e| {
            panic!("point {} failed: {e}", p.key)
        });
        assert_eq!(o.cycles, o.summary.cycles, "point {}", p.key);
        assert_eq!(
            o.summary.attribution.total(),
            o.summary.cycles,
            "point {}: cycles_by_category {:?} does not sum to the \
             point's total cycles",
            p.key,
            o.summary.attribution
        );
        checked += 1;
    }
    assert!(checked >= 6, "only {checked} points checked");
}
