//! Sweep/session integration: the parallel design-space sweep must be a
//! pure parallelisation — every point byte-identical to a sequential
//! single-run execution — whatever tier answered it.  Cached (persistent
//! store) and analytic points must be exactly as deterministic as
//! simulated ones, and the canonical point key must separate sweeps
//! that could otherwise collide (different seeds above all).

use arrow_rvv::bench::profiles;
use arrow_rvv::bench::runner::{run_benchmark, Mode};
use arrow_rvv::bench::suite::Benchmark;
use arrow_rvv::bench::sweep::{report_json, run_sweep, Provenance, SweepSpec};
use arrow_rvv::bench::{analytic, point_key};
use arrow_rvv::system::{MachineBatch, Session};
use arrow_rvv::vector::ArrowConfig;

/// A 24-point grid (2 benchmarks x 1 profile x 2 modes x 3 lane counts
/// x 2 VLENs) swept across a worker pool returns byte-identical
/// per-point `RunSummary` results to sequential single-run execution.
#[test]
fn sweep_is_byte_identical_to_sequential_runs() {
    let spec = SweepSpec {
        benchmarks: vec![Benchmark::VAdd, Benchmark::VDot],
        profiles: vec![profiles::TEST],
        modes: vec![Mode::Scalar, Mode::Vector],
        lanes: vec![1, 2, 4],
        vlens: vec![128, 256],
        seed: 42,
        threads: 4,
        ..Default::default()
    };
    assert_eq!(spec.grid_len(), 24);
    let report = run_sweep(&spec);
    assert_eq!(report.points.len(), 24);
    assert_eq!(report.unique_simulated, 24);
    assert_eq!(report.cache_hits, 0);
    assert_eq!(report.store_hits, 0);
    assert_eq!(report.analytic, 0);

    for point in &report.points {
        let config = ArrowConfig {
            lanes: point.lanes,
            vlen_bits: point.vlen_bits,
            ..Default::default()
        };
        let size = point.benchmark.size(&profiles::TEST);
        let sequential = run_benchmark(
            point.benchmark,
            size,
            point.mode,
            config,
            spec.seed,
        )
        .unwrap();
        let swept = point
            .outcome
            .as_ref()
            .unwrap_or_else(|e| panic!("{}: {e}", point.key));
        assert_eq!(swept.provenance, Provenance::Simulated, "{}", point.key);
        assert!(swept.verified, "{}", point.key);
        assert!(sequential.verified, "{}", point.key);
        assert_eq!(swept.cycles, sequential.cycles, "{}", point.key);
        // Byte-identical ledgers: every field of the summary, including
        // the per-lane busy vector and bus/unit statistics.
        assert_eq!(swept.summary, sequential.summary, "{}", point.key);
        assert_eq!(
            format!("{:?}", swept.summary),
            format!("{:?}", sequential.summary),
            "{}",
            point.key
        );
    }
}

/// The canonical point key folds in the workload seed and the element
/// width, so sweeps that differ only in seed can never collide in the
/// in-request dedup cache or the persistent store.
#[test]
fn point_key_separates_seeds_and_element_widths() {
    let base = ArrowConfig::default();
    let key = point_key(
        Benchmark::VAdd,
        &profiles::TEST,
        Mode::Vector,
        &base,
        42,
    );
    assert!(key.contains("lanes=2"), "{key}");
    assert!(key.contains("vlen=256"), "{key}");
    assert!(key.contains("elen=64"), "{key}");
    assert!(key.contains("seed=42"), "{key}");
    let reseeded = point_key(
        Benchmark::VAdd,
        &profiles::TEST,
        Mode::Vector,
        &base,
        43,
    );
    assert_ne!(key, reseeded);
    let narrow = point_key(
        Benchmark::VAdd,
        &profiles::TEST,
        Mode::Vector,
        &ArrowConfig { elen_bits: 32, ..base },
        42,
    );
    assert_ne!(key, narrow);

    // And the sweep report carries exactly these keys.
    let spec = SweepSpec {
        benchmarks: vec![Benchmark::VAdd],
        profiles: vec![profiles::TEST],
        modes: vec![Mode::Vector],
        lanes: vec![2],
        vlens: vec![256],
        seed: 42,
        threads: 1,
        ..Default::default()
    };
    let report = run_sweep(&spec);
    assert_eq!(report.points[0].key, key);
}

/// The ELEN and timing axes are pure parallelisation too: every point
/// of a multi-precision grid is byte-identical to a sequential
/// single-run execution under the same config, and the ablations
/// genuinely move the cycle model in the direction each preset claims.
#[test]
fn elen_timing_sweep_matches_sequential_runs() {
    let spec = SweepSpec {
        benchmarks: vec![Benchmark::VAdd],
        profiles: vec![profiles::TEST],
        modes: vec![Mode::Vector],
        lanes: vec![2],
        vlens: vec![256],
        elens: vec![32, 64],
        timing: profiles::TIMING_VARIANTS.to_vec(),
        seed: 42,
        threads: 2,
        ..Default::default()
    };
    assert_eq!(spec.grid_len(), 6);
    let report = run_sweep(&spec);
    assert_eq!(report.unique_simulated, 6);
    assert_eq!(report.cache_hits, 0);
    for p in &report.points {
        let variant = profiles::TimingVariant::by_name(p.timing).unwrap();
        let config = variant.apply(ArrowConfig {
            lanes: p.lanes,
            vlen_bits: p.vlen_bits,
            elen_bits: p.elen_bits,
            ..Default::default()
        });
        let size = p.benchmark.size(&profiles::TEST);
        let sequential =
            run_benchmark(p.benchmark, size, p.mode, config, spec.seed)
                .unwrap();
        let swept = p.outcome.as_ref().unwrap();
        assert!(swept.verified, "{}", p.key);
        assert_eq!(swept.cycles, sequential.cycles, "{}", p.key);
        assert_eq!(swept.summary, sequential.summary, "{}", p.key);
    }
    // Order: elens (32, 64) outer, timing variants inner.  The axes
    // move cycles the way the presets claim: a narrower ELEN needs
    // more word passes, a tightly-coupled host and a faster memory
    // interface both beat the baseline.
    let cycles: Vec<u64> = report
        .points
        .iter()
        .map(|p| p.outcome.as_ref().unwrap().cycles)
        .collect();
    let (e32_base, e64_base) = (cycles[0], cycles[3]);
    let (e64_fast, e64_burst) = (cycles[4], cycles[5]);
    assert!(e32_base > e64_base, "{e32_base} vs {e64_base}");
    assert!(e64_fast < e64_base, "{e64_fast} vs {e64_base}");
    assert!(e64_burst < e64_base, "{e64_burst} vs {e64_base}");
}

/// Scalar-mode grid points never touch the vector unit, whatever the
/// Arrow design point says.
#[test]
fn scalar_points_have_no_vector_work() {
    let spec = SweepSpec {
        benchmarks: vec![Benchmark::VAdd],
        profiles: vec![profiles::TEST],
        modes: vec![Mode::Scalar],
        lanes: vec![1, 2],
        vlens: vec![256],
        seed: 3,
        threads: 2,
        ..Default::default()
    };
    let report = run_sweep(&spec);
    for p in &report.points {
        let o = p.outcome.as_ref().unwrap();
        assert_eq!(o.summary.vector_instructions, 0, "{}", p.key);
        assert!(o.summary.lane_busy.iter().all(|&b| b == 0), "{}", p.key);
    }
}

/// Analytic-tier points are exactly as deterministic as simulated ones:
/// a parallel sweep routed through extrapolation returns the same
/// cycles as a sequential [`analytic::extrapolate`] call, run after run.
#[test]
fn analytic_points_match_sequential_extrapolation() {
    let spec = SweepSpec {
        benchmarks: vec![Benchmark::VAdd, Benchmark::VMul],
        profiles: vec![profiles::TEST],
        modes: vec![Mode::Scalar, Mode::Vector],
        lanes: vec![1, 2],
        vlens: vec![256],
        seed: 42,
        threads: 4,
        // A zero limit forces every point through the analytic tier.
        analytic_limit: Some(0),
        ..Default::default()
    };
    let report = run_sweep(&spec);
    assert_eq!(report.analytic, spec.grid_len());
    assert_eq!(report.unique_simulated, 0);
    for p in &report.points {
        let o = p.outcome.as_ref().unwrap();
        assert_eq!(o.provenance, Provenance::Analytic, "{}", p.key);
        let config = ArrowConfig {
            lanes: p.lanes,
            vlen_bits: p.vlen_bits,
            ..Default::default()
        };
        let size = p.benchmark.size(&profiles::TEST);
        let sequential =
            analytic::extrapolate(p.benchmark, size, p.mode, config)
                .unwrap();
        assert_eq!(o.cycles, sequential, "{}", p.key);
    }
    // Parallel evaluation is a pure parallelisation here too.
    let again = run_sweep(&spec);
    for (a, b) in report.points.iter().zip(&again.points) {
        assert_eq!(
            a.outcome.as_ref().unwrap(),
            b.outcome.as_ref().unwrap(),
            "{}",
            a.key
        );
    }
}

/// The lockstep batch engine is a pure optimisation: a sweep over a
/// mixed grid (modes x lanes x VLENs x ELENs x timing variants, so
/// cohorts of every width form) renders byte-identical point JSON with
/// batching on (auto width) and off (`batch_width = 1`, the sequential
/// reference path).
#[test]
fn batched_sweep_byte_identical_to_sequential_path() {
    let spec = SweepSpec {
        benchmarks: vec![Benchmark::VAdd, Benchmark::VDot, Benchmark::VRelu],
        profiles: vec![profiles::TEST],
        modes: vec![Mode::Scalar, Mode::Vector],
        lanes: vec![1, 2, 4],
        vlens: vec![128, 256],
        elens: vec![32, 64],
        timing: profiles::TIMING_VARIANTS.to_vec(),
        seed: 13,
        threads: 4,
        ..Default::default()
    };
    let batched = run_sweep(&spec);
    let sequential =
        run_sweep(&SweepSpec { batch_width: Some(1), ..spec.clone() });
    // The batched run genuinely batched (each vector-mode cohort spans
    // lanes x ELEN x timing at one VLEN) and the reference genuinely
    // did not.
    assert!(batched.batched_points > 0, "{}", batched.batched_points);
    assert!(batched.batch_groups > 0);
    assert_eq!(sequential.batched_points, 0);
    assert_eq!(sequential.batch_groups, 0);
    assert_eq!(batched.unique_simulated, sequential.unique_simulated);
    // Byte-identity over the full rendered point rows — cycles,
    // ledgers, energy, provenance, everything.
    assert_eq!(
        report_json(&batched).get("points").unwrap().to_string(),
        report_json(&sequential).get("points").unwrap().to_string()
    );
}

/// Lockstep execution handles the awkward instruction classes too:
/// masked ALU ops (`v0.t`), `vmerge`, mask-producing compares, and
/// indexed (gather/scatter) memory accesses.  Every member of a mixed
/// lanes/ELEN/timing batch must match its own solo [`Session`] run,
/// ledger and memory image alike.
#[test]
fn lockstep_batch_matches_sessions_on_masked_and_indexed_ops() {
    use arrow_rvv::asm::assemble;
    use arrow_rvv::isa::decode;
    use arrow_rvv::scalar::ScalarTiming;

    let src = r#"
        .data
        idx: .word 28, 0, 20, 8, 4, 24, 12, 16
        xs: .word -3, 7, -11, 19, -23, 2, -9, 31
        ys: .space 32
        zs: .space 32
        .text
            li a2, 8
            vsetvli t0, a2, e32,m1
            la a0, idx
            vle32.v v2, (a0)
            la a0, xs
            vlxei32.v v1, (a0), v2      # gather xs[idx/4]
            vmslt.vx v0, v1, zero       # mask = gathered < 0
            vmerge.vxm v3, v1, 0, v0    # relu: negatives -> 0
            vadd.vv v4, v1, v1, v0.t    # masked: double the negatives
            la a0, ys
            vse32.v v3, (a0)
            la a0, zs
            vsxei32.v v4, (a0), v2      # scatter back through idx
            halt
    "#;
    let program = assemble(src).unwrap();
    let decoded: Vec<_> =
        program.text.iter().map(|&w| decode(w).ok()).collect();

    // One cohort (VLEN 256, indexed on), every free axis exercised.
    let variants = profiles::TIMING_VARIANTS;
    let configs: Vec<ArrowConfig> = [
        (1usize, 32u32, &variants[0]),
        (1, 64, &variants[1]),
        (2, 32, &variants[2]),
        (2, 64, &variants[0]),
        (4, 32, &variants[1]),
        (4, 64, &variants[2]),
    ]
    .into_iter()
    .map(|(lanes, elen_bits, variant)| {
        variant.apply(ArrowConfig {
            lanes,
            elen_bits,
            vlen_bits: 256,
            indexed_mem: true,
            ..Default::default()
        })
    })
    .collect();

    let mut batch = MachineBatch::new(
        program.clone(),
        decoded,
        configs.clone(),
        ScalarTiming::default(),
    )
    .unwrap();
    let summaries = batch.run(100_000).unwrap();

    // The shared architectural trace did what the program says: the
    // gather permuted xs, the merge relu'd it, the masked add doubled
    // only the negatives, the scatter permuted them back.
    let ys = batch.dram.read_i32_slice(batch.addr_of("ys"), 8);
    assert_eq!(ys, vec![31, 0, 2, 0, 7, 0, 19, 0]);
    let zs = batch.dram.read_i32_slice(batch.addr_of("zs"), 8);
    assert_eq!(zs, vec![-6, 0, -22, 0, -46, 0, -18, 0]);

    for (config, summary) in configs.iter().zip(&summaries) {
        let session = Session::new(program.clone(), *config).unwrap();
        let mut solo = session.machine();
        let solo_summary = solo.run(100_000).unwrap();
        assert_eq!(summary, &solo_summary, "lanes={}", config.lanes);
        assert_eq!(ys, solo.dram.read_i32_slice(solo.addr_of("ys"), 8));
        assert_eq!(zs, solo.dram.read_i32_slice(solo.addr_of("zs"), 8));
    }
}

/// Superinstruction fusion is cycle-model-neutral: a sealed, fused
/// session machine reports the exact ledger of a lazy, unfused
/// [`Machine`] over a branchy strip-mined loop — the code shape fusion
/// targets (`vsetvli`+op and op+back-edge pairs every iteration).
#[test]
fn fusion_is_cycle_neutral_on_stripmined_loops() {
    use arrow_rvv::asm::assemble;
    use arrow_rvv::scalar::ScalarTiming;
    use arrow_rvv::system::Machine;

    let src = r#"
        .data
        xs: .word 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15, 16
        out: .space 64
        .text
            li a1, 16
            la a3, xs
            la a4, out
        loop:
            vsetvli t0, a1, e32,m1
            vle32.v v1, (a3)
            vadd.vv v2, v1, v1
            vse32.v v2, (a4)
            slli t1, t0, 2
            add a3, a3, t1
            add a4, a4, t1
            sub a1, a1, t0
            bnez a1, loop
            halt
    "#;
    let program = assemble(src).unwrap();
    for vlen_bits in [128u32, 256] {
        let config = ArrowConfig { vlen_bits, ..Default::default() };
        let fused =
            Session::new(program.clone(), config).unwrap().run(
                &[],
                Some(("out", 16)),
                100_000,
            )
            .unwrap();
        let mut plain =
            Machine::new(program.clone(), config, ScalarTiming::default());
        let summary = plain.run(100_000).unwrap();
        let out = plain.dram.read_i32_slice(plain.addr_of("out"), 16);
        assert_eq!(fused.summary, summary, "vlen={vlen_bits}");
        assert_eq!(fused.output, out);
        assert_eq!(
            out,
            (1..=16).map(|x| 2 * x).collect::<Vec<i32>>(),
            "vlen={vlen_bits}"
        );
    }
}

/// A session built once serves many workloads with ledgers identical to
/// fresh per-run machines — the "build once, run many" contract the
/// sweep pool relies on.
#[test]
fn session_reuse_is_equivalent_to_fresh_machines() {
    use arrow_rvv::asm::assemble;
    use arrow_rvv::scalar::ScalarTiming;
    use arrow_rvv::system::Machine;

    let src = r#"
        .data
        xs: .word 0, 0, 0, 0, 0, 0, 0, 0
        ys: .space 32
        .text
            li a2, 8
            vsetvli t0, a2, e32,m1
            la a0, xs
            vle32.v v1, (a0)
            vadd.vv v2, v1, v1
            la a0, ys
            vse32.v v2, (a0)
            halt
    "#;
    let program = assemble(src).unwrap();
    let session =
        Session::new(program.clone(), ArrowConfig::default()).unwrap();
    for seed in 0..3i32 {
        let xs: Vec<i32> = (0..8).map(|i| i * 7 + seed).collect();
        let from_session =
            session.run(&[("xs", &xs)], Some(("ys", 8)), 10_000).unwrap();
        let mut fresh = Machine::new(
            program.clone(),
            ArrowConfig::default(),
            ScalarTiming::default(),
        );
        let addr = fresh.addr_of("xs");
        fresh.dram.write_i32_slice(addr, &xs);
        let summary = fresh.run(10_000).unwrap();
        let out = fresh.dram.read_i32_slice(fresh.addr_of("ys"), 8);
        assert_eq!(from_session.summary, summary);
        assert_eq!(from_session.output, out);
        assert_eq!(
            from_session.output,
            xs.iter().map(|x| 2 * x).collect::<Vec<i32>>()
        );
    }
}
