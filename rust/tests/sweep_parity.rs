//! Sweep/session integration: the parallel design-space sweep must be a
//! pure parallelisation — every point byte-identical to a sequential
//! single-run execution — whatever tier answered it.  Cached (persistent
//! store) and analytic points must be exactly as deterministic as
//! simulated ones, and the canonical point key must separate sweeps
//! that could otherwise collide (different seeds above all).

use arrow_rvv::bench::profiles;
use arrow_rvv::bench::runner::{run_benchmark, Mode};
use arrow_rvv::bench::suite::Benchmark;
use arrow_rvv::bench::sweep::{run_sweep, Provenance, SweepSpec};
use arrow_rvv::bench::{analytic, point_key};
use arrow_rvv::system::Session;
use arrow_rvv::vector::ArrowConfig;

/// A 24-point grid (2 benchmarks x 1 profile x 2 modes x 3 lane counts
/// x 2 VLENs) swept across a worker pool returns byte-identical
/// per-point `RunSummary` results to sequential single-run execution.
#[test]
fn sweep_is_byte_identical_to_sequential_runs() {
    let spec = SweepSpec {
        benchmarks: vec![Benchmark::VAdd, Benchmark::VDot],
        profiles: vec![profiles::TEST],
        modes: vec![Mode::Scalar, Mode::Vector],
        lanes: vec![1, 2, 4],
        vlens: vec![128, 256],
        seed: 42,
        threads: 4,
        ..Default::default()
    };
    assert_eq!(spec.grid_len(), 24);
    let report = run_sweep(&spec);
    assert_eq!(report.points.len(), 24);
    assert_eq!(report.unique_simulated, 24);
    assert_eq!(report.cache_hits, 0);
    assert_eq!(report.store_hits, 0);
    assert_eq!(report.analytic, 0);

    for point in &report.points {
        let config = ArrowConfig {
            lanes: point.lanes,
            vlen_bits: point.vlen_bits,
            ..Default::default()
        };
        let size = point.benchmark.size(&profiles::TEST);
        let sequential = run_benchmark(
            point.benchmark,
            size,
            point.mode,
            config,
            spec.seed,
        )
        .unwrap();
        let swept = point
            .outcome
            .as_ref()
            .unwrap_or_else(|e| panic!("{}: {e}", point.key));
        assert_eq!(swept.provenance, Provenance::Simulated, "{}", point.key);
        assert!(swept.verified, "{}", point.key);
        assert!(sequential.verified, "{}", point.key);
        assert_eq!(swept.cycles, sequential.cycles, "{}", point.key);
        // Byte-identical ledgers: every field of the summary, including
        // the per-lane busy vector and bus/unit statistics.
        assert_eq!(swept.summary, sequential.summary, "{}", point.key);
        assert_eq!(
            format!("{:?}", swept.summary),
            format!("{:?}", sequential.summary),
            "{}",
            point.key
        );
    }
}

/// The canonical point key folds in the workload seed and the element
/// width, so sweeps that differ only in seed can never collide in the
/// in-request dedup cache or the persistent store.
#[test]
fn point_key_separates_seeds_and_element_widths() {
    let base = ArrowConfig::default();
    let key = point_key(
        Benchmark::VAdd,
        &profiles::TEST,
        Mode::Vector,
        &base,
        42,
    );
    assert!(key.contains("lanes=2"), "{key}");
    assert!(key.contains("vlen=256"), "{key}");
    assert!(key.contains("elen=64"), "{key}");
    assert!(key.contains("seed=42"), "{key}");
    let reseeded = point_key(
        Benchmark::VAdd,
        &profiles::TEST,
        Mode::Vector,
        &base,
        43,
    );
    assert_ne!(key, reseeded);
    let narrow = point_key(
        Benchmark::VAdd,
        &profiles::TEST,
        Mode::Vector,
        &ArrowConfig { elen_bits: 32, ..base },
        42,
    );
    assert_ne!(key, narrow);

    // And the sweep report carries exactly these keys.
    let spec = SweepSpec {
        benchmarks: vec![Benchmark::VAdd],
        profiles: vec![profiles::TEST],
        modes: vec![Mode::Vector],
        lanes: vec![2],
        vlens: vec![256],
        seed: 42,
        threads: 1,
        ..Default::default()
    };
    let report = run_sweep(&spec);
    assert_eq!(report.points[0].key, key);
}

/// The ELEN and timing axes are pure parallelisation too: every point
/// of a multi-precision grid is byte-identical to a sequential
/// single-run execution under the same config, and the ablations
/// genuinely move the cycle model in the direction each preset claims.
#[test]
fn elen_timing_sweep_matches_sequential_runs() {
    let spec = SweepSpec {
        benchmarks: vec![Benchmark::VAdd],
        profiles: vec![profiles::TEST],
        modes: vec![Mode::Vector],
        lanes: vec![2],
        vlens: vec![256],
        elens: vec![32, 64],
        timing: profiles::TIMING_VARIANTS.to_vec(),
        seed: 42,
        threads: 2,
        ..Default::default()
    };
    assert_eq!(spec.grid_len(), 6);
    let report = run_sweep(&spec);
    assert_eq!(report.unique_simulated, 6);
    assert_eq!(report.cache_hits, 0);
    for p in &report.points {
        let variant = profiles::TimingVariant::by_name(p.timing).unwrap();
        let config = variant.apply(ArrowConfig {
            lanes: p.lanes,
            vlen_bits: p.vlen_bits,
            elen_bits: p.elen_bits,
            ..Default::default()
        });
        let size = p.benchmark.size(&profiles::TEST);
        let sequential =
            run_benchmark(p.benchmark, size, p.mode, config, spec.seed)
                .unwrap();
        let swept = p.outcome.as_ref().unwrap();
        assert!(swept.verified, "{}", p.key);
        assert_eq!(swept.cycles, sequential.cycles, "{}", p.key);
        assert_eq!(swept.summary, sequential.summary, "{}", p.key);
    }
    // Order: elens (32, 64) outer, timing variants inner.  The axes
    // move cycles the way the presets claim: a narrower ELEN needs
    // more word passes, a tightly-coupled host and a faster memory
    // interface both beat the baseline.
    let cycles: Vec<u64> = report
        .points
        .iter()
        .map(|p| p.outcome.as_ref().unwrap().cycles)
        .collect();
    let (e32_base, e64_base) = (cycles[0], cycles[3]);
    let (e64_fast, e64_burst) = (cycles[4], cycles[5]);
    assert!(e32_base > e64_base, "{e32_base} vs {e64_base}");
    assert!(e64_fast < e64_base, "{e64_fast} vs {e64_base}");
    assert!(e64_burst < e64_base, "{e64_burst} vs {e64_base}");
}

/// Scalar-mode grid points never touch the vector unit, whatever the
/// Arrow design point says.
#[test]
fn scalar_points_have_no_vector_work() {
    let spec = SweepSpec {
        benchmarks: vec![Benchmark::VAdd],
        profiles: vec![profiles::TEST],
        modes: vec![Mode::Scalar],
        lanes: vec![1, 2],
        vlens: vec![256],
        seed: 3,
        threads: 2,
        ..Default::default()
    };
    let report = run_sweep(&spec);
    for p in &report.points {
        let o = p.outcome.as_ref().unwrap();
        assert_eq!(o.summary.vector_instructions, 0, "{}", p.key);
        assert!(o.summary.lane_busy.iter().all(|&b| b == 0), "{}", p.key);
    }
}

/// Analytic-tier points are exactly as deterministic as simulated ones:
/// a parallel sweep routed through extrapolation returns the same
/// cycles as a sequential [`analytic::extrapolate`] call, run after run.
#[test]
fn analytic_points_match_sequential_extrapolation() {
    let spec = SweepSpec {
        benchmarks: vec![Benchmark::VAdd, Benchmark::VMul],
        profiles: vec![profiles::TEST],
        modes: vec![Mode::Scalar, Mode::Vector],
        lanes: vec![1, 2],
        vlens: vec![256],
        seed: 42,
        threads: 4,
        // A zero limit forces every point through the analytic tier.
        analytic_limit: Some(0),
        ..Default::default()
    };
    let report = run_sweep(&spec);
    assert_eq!(report.analytic, spec.grid_len());
    assert_eq!(report.unique_simulated, 0);
    for p in &report.points {
        let o = p.outcome.as_ref().unwrap();
        assert_eq!(o.provenance, Provenance::Analytic, "{}", p.key);
        let config = ArrowConfig {
            lanes: p.lanes,
            vlen_bits: p.vlen_bits,
            ..Default::default()
        };
        let size = p.benchmark.size(&profiles::TEST);
        let sequential =
            analytic::extrapolate(p.benchmark, size, p.mode, config)
                .unwrap();
        assert_eq!(o.cycles, sequential, "{}", p.key);
    }
    // Parallel evaluation is a pure parallelisation here too.
    let again = run_sweep(&spec);
    for (a, b) in report.points.iter().zip(&again.points) {
        assert_eq!(
            a.outcome.as_ref().unwrap(),
            b.outcome.as_ref().unwrap(),
            "{}",
            a.key
        );
    }
}

/// A session built once serves many workloads with ledgers identical to
/// fresh per-run machines — the "build once, run many" contract the
/// sweep pool relies on.
#[test]
fn session_reuse_is_equivalent_to_fresh_machines() {
    use arrow_rvv::asm::assemble;
    use arrow_rvv::scalar::ScalarTiming;
    use arrow_rvv::system::Machine;

    let src = r#"
        .data
        xs: .word 0, 0, 0, 0, 0, 0, 0, 0
        ys: .space 32
        .text
            li a2, 8
            vsetvli t0, a2, e32,m1
            la a0, xs
            vle32.v v1, (a0)
            vadd.vv v2, v1, v1
            la a0, ys
            vse32.v v2, (a0)
            halt
    "#;
    let program = assemble(src).unwrap();
    let session =
        Session::new(program.clone(), ArrowConfig::default()).unwrap();
    for seed in 0..3i32 {
        let xs: Vec<i32> = (0..8).map(|i| i * 7 + seed).collect();
        let from_session =
            session.run(&[("xs", &xs)], Some(("ys", 8)), 10_000).unwrap();
        let mut fresh = Machine::new(
            program.clone(),
            ArrowConfig::default(),
            ScalarTiming::default(),
        );
        let addr = fresh.addr_of("xs");
        fresh.dram.write_i32_slice(addr, &xs);
        let summary = fresh.run(10_000).unwrap();
        let out = fresh.dram.read_i32_slice(fresh.addr_of("ys"), 8);
        assert_eq!(from_session.summary, summary);
        assert_eq!(from_session.output, out);
        assert_eq!(
            from_session.output,
            xs.iter().map(|x| 2 * x).collect::<Vec<i32>>()
        );
    }
}
