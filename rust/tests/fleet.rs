//! Fleet-registration lifecycle: a sweep started with **zero**
//! pre-listed workers completes via workers that `--join` after it
//! starts; heartbeat expiry drains a worker like a death (its pending
//! work requeues into the fallback path); version-mismatched
//! registrations are refused over the wire; and the adaptive shard
//! costing genuinely shrinks later shards after slow worker reports —
//! all against in-process fleets binding port 0, with every merged
//! report byte-identical to a local run of the same spec.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use arrow_rvv::bench::cluster::{run_cluster, ClusterSpec};
use arrow_rvv::bench::fleet::{self, Membership, Registration};
use arrow_rvv::bench::profiles;
use arrow_rvv::bench::runner::Mode;
use arrow_rvv::bench::suite::Benchmark;
use arrow_rvv::bench::sweep::{report_json, run_sweep, SweepSpec};
use arrow_rvv::bench::Evaluator;
use arrow_rvv::system::server;
use arrow_rvv::util::json::{self, Json};

/// Bind port 0, learn the address, and serve a real worker on a
/// background thread (leaked; the test process' exit reaps it).
fn spawn_worker() -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    thread::spawn(move || {
        let _ = server::serve_listener(listener, None);
    });
    addr
}

/// A worker that answers every request through the real handler, then
/// lets `transform(request, response)` rewrite the response — how the
/// tests fake a slow worker (sleep before answering batches) and a
/// worker reporting absurd measured wall-times.
fn spawn_custom_worker(
    transform: impl Fn(&Json, Json) -> Json + Send + Sync + 'static,
) -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let evaluator = Arc::new(Evaluator::new());
    let transform = Arc::new(transform);
    thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(stream) = stream else { break };
            let evaluator = Arc::clone(&evaluator);
            let transform = Arc::clone(&transform);
            thread::spawn(move || {
                let Ok(mut writer) = stream.try_clone() else { return };
                let reader = BufReader::new(stream);
                for line in reader.lines() {
                    let Ok(line) = line else { break };
                    if line.trim().is_empty() {
                        continue;
                    }
                    let Ok(req) = json::parse(line.trim()) else { break };
                    let resp = server::handle_request(&req, &evaluator);
                    let resp = transform(&req, resp);
                    if writeln!(writer, "{resp}").is_err() {
                        break;
                    }
                }
            });
        }
    });
    addr
}

/// One `register` round trip against a live registry endpoint.
fn register_over_wire(registry: &str, worker: &str, version: &str) -> Json {
    let mut stream = TcpStream::connect(registry).unwrap();
    writeln!(
        stream,
        r#"{{"cmd": "register", "addr": "{worker}", "version": "{version}"}}"#
    )
    .unwrap();
    let mut line = String::new();
    BufReader::new(stream.try_clone().unwrap())
        .read_line(&mut line)
        .unwrap();
    json::parse(line.trim()).unwrap()
}

fn registration(addr: &str) -> Registration {
    Registration {
        addr: addr.to_string(),
        version: env!("CARGO_PKG_VERSION").to_string(),
        max_grid: 4096,
        max_batch: 256,
        in_flight: 0,
        sweeps_served: 0,
        ledger: None,
    }
}

fn parity_spec() -> SweepSpec {
    SweepSpec {
        benchmarks: vec![Benchmark::VAdd, Benchmark::VDot],
        profiles: vec![profiles::TEST],
        modes: vec![Mode::Scalar, Mode::Vector],
        lanes: vec![1, 2],
        vlens: vec![128, 256],
        seed: 42,
        threads: 2,
        ..Default::default()
    }
}

fn points_json(report: &arrow_rvv::bench::SweepReport) -> String {
    report_json(report).get("points").unwrap().to_string()
}

/// The acceptance shape of the self-organising fleet: a cluster sweep
/// started with an empty worker list completes entirely via a worker
/// that registers *after* the sweep starts, and the merged per-point
/// JSON — energy field included — is byte-identical to a local run.
#[test]
fn worker_joining_mid_sweep_picks_up_all_shards() {
    let spec = parity_spec();
    let local = run_sweep(&spec);
    let membership = Membership::shared_with_expiry(Duration::from_secs(60));
    let registry =
        fleet::serve_registry_on("127.0.0.1:0", &membership).unwrap();
    let worker = spawn_worker();
    {
        let registry = registry.clone();
        let worker = worker.clone();
        thread::spawn(move || {
            // Join well after the coordinator started waiting.
            thread::sleep(Duration::from_millis(300));
            register_over_wire(
                &registry,
                &worker,
                env!("CARGO_PKG_VERSION"),
            );
        });
    }
    let mut cs = ClusterSpec::new(spec, Vec::new());
    cs.membership = Some(membership);
    cs.join_grace = Duration::from_secs(60);
    cs.shard_points = 4;
    cs.shards_per_batch = 1;
    let cluster = run_cluster(&cs).unwrap();

    assert_eq!(cluster.local_shards, 0, "the joiner must do all the work");
    assert_eq!(cluster.workers.len(), 1);
    let w = &cluster.workers[0];
    assert_eq!(w.addr, worker);
    assert!(w.joined, "must be recorded as fleet-joined, not pre-listed");
    assert!(w.error.is_none(), "{:?}", w.error);
    assert_eq!(w.shards, cluster.shards);
    assert!(w.caps.is_some());
    assert_eq!(points_json(&cluster.report), points_json(&local));
}

/// A registered worker whose heartbeats stop is expired and drained
/// exactly like a dead worker: no new batches, remaining shards land
/// in the requeue/local-fallback path, and the merged report is still
/// byte-identical to a local run.
#[test]
fn heartbeat_expiry_drains_worker_into_fallback() {
    let spec = parity_spec();
    let local = run_sweep(&spec);
    // Slow worker: every batch takes ~600 ms, far past the 250 ms
    // expiry — so after (at most) one merged batch the coordinator
    // sees the heartbeat lapse and drains it.
    let worker = spawn_custom_worker(|req, resp| {
        if req.get("cmd").and_then(Json::as_str) == Some("batch") {
            thread::sleep(Duration::from_millis(600));
        }
        resp
    });
    let membership =
        Membership::shared_with_expiry(Duration::from_millis(250));
    // Register once, directly into the table (the wire path is covered
    // elsewhere) — and never heartbeat again.
    membership.register(&registration(&worker)).unwrap();
    let mut cs = ClusterSpec::new(spec, Vec::new());
    cs.membership = Some(membership);
    cs.shard_points = 4;
    cs.shards_per_batch = 1;
    let cluster = run_cluster(&cs).unwrap();

    let w = &cluster.workers[0];
    assert!(
        w.error.as_deref().is_some_and(|e| e.contains("expired")),
        "worker must be drained by heartbeat expiry: {:?}",
        w.error
    );
    assert!(
        cluster.local_shards >= 1,
        "the drained worker's remaining shards must requeue into the \
         local fallback"
    );
    assert_eq!(w.shards + cluster.local_shards, cluster.shards);
    assert_eq!(points_json(&cluster.report), points_json(&local));
}

/// A version-mismatched `register` is refused over the wire and never
/// enters the membership table; a matching one is welcomed and told
/// the expiry it must out-pace.
#[test]
fn version_mismatched_registration_refused_over_the_wire() {
    let membership = Membership::shared();
    let registry =
        fleet::serve_registry_on("127.0.0.1:0", &membership).unwrap();
    let resp = register_over_wire(&registry, "127.0.0.1:1", "99.0.0");
    assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
    let err = resp.get("error").unwrap().as_str().unwrap();
    assert!(err.contains("99.0.0"), "{err}");
    assert!(err.contains(env!("CARGO_PKG_VERSION")), "{err}");
    assert!(err.contains("refused"), "{err}");
    assert_eq!(membership.live_count(), 0);

    let resp = register_over_wire(
        &registry,
        "127.0.0.1:1",
        env!("CARGO_PKG_VERSION"),
    );
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
    assert!(resp.get("expiry_ms").unwrap().as_u64().unwrap() > 0);
    assert_eq!(membership.live_count(), 1);
}

/// The measured-cost feedback loop end to end: a worker that reports
/// absurdly slow shard wall-times makes the coordinator shrink every
/// later carve down to single points — visibly smaller shards — while
/// the merged report stays byte-identical to a local run (adaptivity
/// may only move shard boundaries, never results).
#[test]
fn adaptive_shard_cost_shrinks_after_slow_reports() {
    let spec = SweepSpec {
        benchmarks: vec![Benchmark::VAdd],
        profiles: vec![profiles::TEST],
        modes: vec![Mode::Vector],
        lanes: vec![1, 2],
        vlens: vec![128, 256],
        elens: vec![32, 64],
        timing: vec![profiles::TIMING_BASELINE, profiles::TIMING_BURST_MEM],
        seed: 42,
        threads: 2,
        ..Default::default()
    };
    assert_eq!(spec.grid_len(), 16);
    let local = run_sweep(&spec);
    // Evaluate honestly, then report every shard as having taken 1e12
    // ms: the EWMA collapses the carve budget to its floor.
    let worker = spawn_custom_worker(|_req, mut resp| {
        if let Json::Obj(map) = &mut resp {
            if let Some(Json::Arr(subs)) = map.get_mut("responses") {
                for sub in subs {
                    if let Json::Obj(m) = sub {
                        if m.contains_key("elapsed_ms") {
                            m.insert("elapsed_ms".into(), Json::Num(1e12));
                        }
                    }
                }
            }
        }
        resp
    });
    let mut cs = ClusterSpec::new(spec, vec![worker]);
    let initial_cost = cs.shard_cost;
    cs.shard_points = 8;
    cs.shards_per_batch = 1;
    let cluster = run_cluster(&cs).unwrap();

    assert_eq!(cluster.local_shards, 0);
    // First shard carved under the initial budget: the full 8 points.
    assert_eq!(cluster.shard_sizes[0], 8, "{:?}", cluster.shard_sizes);
    // After the first slow report every later carve is a single point.
    assert_eq!(
        *cluster.shard_sizes.last().unwrap(),
        1,
        "{:?}",
        cluster.shard_sizes
    );
    assert!(cluster.shards > 4, "{:?}", cluster.shard_sizes);
    assert!(cluster.final_shard_cost < initial_cost);
    assert_eq!(points_json(&cluster.report), points_json(&local));
}
