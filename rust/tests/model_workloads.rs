//! End-to-end model workloads: golden-output regression against the
//! checked-in Python-generated fixtures (no Python at test time), the
//! versioned model-program manifest pinned against the built-in
//! registry, and sweep parity — a model point must come out
//! byte-identical whether evaluated locally (auto or sequential batch
//! width) or merged from a worker fleet, and must come from the store
//! on a repeated cached sweep.

use std::net::TcpListener;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::thread;

use arrow_rvv::bench::cluster::{run_cluster, ClusterSpec};
use arrow_rvv::bench::eval::SessionPool;
use arrow_rvv::bench::models::{ModelId, MODELS};
use arrow_rvv::bench::runner::{Mode, DEFAULT_BUDGET};
use arrow_rvv::bench::sweep::{report_json, run_sweep, SweepSpec};
use arrow_rvv::bench::ProgramCache;
use arrow_rvv::bench::suite::Benchmark;
use arrow_rvv::system::{server, ModelSession};
use arrow_rvv::util::json::{self, Json};
use arrow_rvv::vector::ArrowConfig;

fn golden_path(file: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden")
        .join(file)
}

fn load_golden(file: &str) -> Json {
    let content = std::fs::read_to_string(golden_path(file))
        .unwrap_or_else(|e| panic!("fixture {file}: {e}"));
    json::parse(&content).unwrap_or_else(|e| panic!("fixture {file}: {e}"))
}

fn int_vec(j: &Json) -> Vec<i32> {
    j.as_arr()
        .expect("tensor must be an array")
        .iter()
        .map(|v| v.as_f64().expect("tensor element must be a number") as i32)
        .collect()
}

/// The `model.BENCH_OPS` key the Python AOT pipeline uses for each
/// suite benchmark — the manifest's per-stage kernel refs.
fn kernel_ref(b: Benchmark) -> &'static str {
    match b {
        Benchmark::VAdd => "vadd",
        Benchmark::VMul => "vmul",
        Benchmark::VDot => "dot",
        Benchmark::VMaxReduce => "max_reduce",
        Benchmark::VRelu => "relu",
        Benchmark::MatAdd => "matadd",
        Benchmark::MatMul => "matmul",
        Benchmark::MaxPool => "maxpool",
        Benchmark::Conv2d => "conv2d",
    }
}

/// Every checked-in fixture tensor matches the simulator bit-exactly:
/// the workload generator (input + composed per-stage oracles) and the
/// simulated `ModelSession` output both agree with the Python mirror,
/// at every fixture seed, in both modes.
#[test]
fn golden_fixtures_pin_model_session_output() {
    let programs = ProgramCache::new();
    let sessions = SessionPool::default();
    for m in MODELS {
        let fixtures = load_golden(&format!("{}.json", m.name()));
        let fixtures = fixtures.as_arr().expect("fixture file is an array");
        assert!(!fixtures.is_empty(), "{}: empty fixture", m.name());
        for fx in fixtures {
            assert_eq!(
                fx.get("format").and_then(Json::as_str),
                Some("arrow-model-golden")
            );
            assert_eq!(fx.get("version").and_then(Json::as_u64), Some(1));
            let seed = fx.get("seed").and_then(Json::as_u64).unwrap();
            let expected = int_vec(fx.get("expected").unwrap());

            // The Rust workload generator agrees with the Python mirror
            // stream-for-stream: same input draw, same composed oracle
            // tensor after every stage.
            let w = m.workload(seed);
            assert_eq!(
                w.stages[0].inputs[0].1,
                int_vec(fx.get("input").unwrap()),
                "{} seed {seed}: input draw drifted",
                m.name()
            );
            let fx_stages = fx.get("stages").unwrap().as_arr().unwrap();
            assert_eq!(fx_stages.len(), m.stages().len());
            for (k, (st, fx_st)) in
                m.stages().iter().zip(fx_stages).enumerate()
            {
                assert_eq!(
                    fx_st.get("name").and_then(Json::as_str),
                    Some(st.name)
                );
                assert_eq!(
                    w.stages[k].expected,
                    int_vec(fx_st.get("expected").unwrap()),
                    "{} seed {seed} stage {}: oracle drifted",
                    m.name(),
                    st.name
                );
            }
            assert_eq!(w.expected, expected);

            // And the simulated end-to-end run reproduces the fixture
            // bit-exactly in both modes.
            for mode in [Mode::Scalar, Mode::Vector] {
                let ms = ModelSession::build(
                    m,
                    mode,
                    ArrowConfig::default(),
                    &programs,
                    &sessions,
                )
                .unwrap();
                let run = ms.run(seed, DEFAULT_BUDGET).unwrap();
                assert!(run.verified, "{} seed {seed} {mode:?}", m.name());
                assert_eq!(
                    run.output,
                    expected,
                    "{} seed {seed} {mode:?}: simulated output != fixture",
                    m.name()
                );
            }
        }
    }
}

/// The versioned model-program manifest the Python AOT pipeline emits
/// (`aot.py --models-out`, checked in) describes exactly the stage
/// chains the Rust built-in registry hand-writes.
#[test]
fn model_program_manifest_matches_builtin_registry() {
    let manifest = load_golden("model_programs.json");
    assert_eq!(
        manifest.get("format").and_then(Json::as_str),
        Some("arrow-model-program")
    );
    assert_eq!(manifest.get("version").and_then(Json::as_u64), Some(1));
    let models = manifest.get("models").unwrap();
    let listed = models.as_obj().unwrap();
    assert_eq!(listed.len(), MODELS.len());
    for m in MODELS {
        let program = models
            .get(m.name())
            .unwrap_or_else(|| panic!("{} missing from manifest", m.name()));
        assert_eq!(
            program.get("description").and_then(Json::as_str),
            Some(m.def().description),
            "{}",
            m.name()
        );
        let stages = program.get("stages").unwrap().as_arr().unwrap();
        assert_eq!(stages.len(), m.stages().len(), "{}", m.name());
        for (st, js) in m.stages().iter().zip(stages) {
            assert_eq!(js.get("name").and_then(Json::as_str), Some(st.name));
            assert_eq!(
                js.get("kernel").and_then(Json::as_str),
                Some(kernel_ref(st.benchmark)),
                "{} stage {}",
                m.name(),
                st.name
            );
            let size = js.get("size").unwrap();
            let field = |k: &str| size.get(k).and_then(Json::as_u64).unwrap();
            assert_eq!(field("n") as usize, st.size.n);
            assert_eq!(field("k") as usize, st.size.k);
            assert_eq!(field("batch") as usize, st.size.batch);
        }
    }
}

fn model_spec() -> SweepSpec {
    SweepSpec {
        benchmarks: vec![],
        models: vec![ModelId::VecChain, ModelId::Mlp],
        modes: vec![Mode::Vector],
        lanes: vec![1, 2],
        vlens: vec![128],
        seed: 11,
        threads: 1,
        ..Default::default()
    }
}

fn points_json(report: &arrow_rvv::bench::SweepReport) -> String {
    report_json(report).get("points").unwrap().to_string()
}

fn spawn_worker() -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    thread::spawn(move || {
        let _ = server::serve_listener(listener, None);
    });
    addr
}

/// A model point comes out byte-identical from every evaluation path:
/// local auto batch width, explicit sequential width, and a two-worker
/// cluster merge.
#[test]
fn model_sweep_parity_across_local_batched_and_cluster() {
    let spec = model_spec();
    let auto = run_sweep(&spec);
    assert_eq!(auto.points.len(), spec.grid_len());
    assert!(auto.points.iter().all(|p| p.outcome.is_ok()));

    let sequential =
        SweepSpec { batch_width: Some(1), ..spec.clone() };
    let sequential = run_sweep(&sequential);
    assert_eq!(points_json(&auto), points_json(&sequential));

    let workers = vec![spawn_worker(), spawn_worker()];
    let mut cs = ClusterSpec::new(spec, workers);
    cs.shard_points = 1;
    cs.shards_per_batch = 1;
    let cluster = run_cluster(&cs).unwrap();
    assert_eq!(cluster.local_shards, 0, "no fallback on a healthy fleet");
    assert_eq!(points_json(&auto), points_json(&cluster.report));

    // Every merged model row still carries its per-stage sub-ledgers,
    // and they sum exactly to the row's cycle total.
    for p in report_json(&cluster.report)
        .get("points")
        .unwrap()
        .as_arr()
        .unwrap()
    {
        let stages = p.get("stages").unwrap().as_arr().unwrap();
        assert!(!stages.is_empty());
        let sum: u64 = stages
            .iter()
            .map(|s| s.get("cycles").and_then(Json::as_u64).unwrap())
            .sum();
        assert_eq!(Some(sum), p.get("cycles").and_then(Json::as_u64));
    }
}

fn tmp_dir() -> PathBuf {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    let dir = std::env::temp_dir().join(format!(
        "arrow-model-sweep-{}-{}",
        std::process::id(),
        NEXT.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A repeated `--cache-dir` model sweep answers entirely from the
/// result store: zero points re-simulated on the second pass.
#[test]
fn repeated_cached_model_sweep_simulates_nothing() {
    let dir = tmp_dir();
    let spec =
        SweepSpec { cache_dir: Some(dir.clone()), ..model_spec() };

    let first = run_sweep(&spec);
    assert!(first.store_error.is_none(), "{:?}", first.store_error);
    assert_eq!(first.unique_simulated, spec.grid_len());
    assert_eq!(first.store_hits, 0);

    let second = run_sweep(&spec);
    assert!(second.store_error.is_none(), "{:?}", second.store_error);
    assert_eq!(second.unique_simulated, 0, "model points were re-simulated");
    assert_eq!(second.store_hits, spec.grid_len());
    assert_eq!(points_json(&first), points_json(&second));

    // Stage sub-ledgers survive the store round-trip too.
    for p in &second.points {
        let o = p.outcome.as_ref().unwrap();
        assert!(!o.stages.is_empty(), "{}: stages lost in store", p.key);
        let sum: u64 = o.stages.iter().map(|s| s.cycles).sum();
        assert_eq!(sum, o.cycles, "{}", p.key);
    }
    let _ = std::fs::remove_dir_all(&dir);
}
