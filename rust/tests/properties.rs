//! Property-based tests over the coordinator and ISA invariants
//! (routing, batching/bursts, register state), driven by the in-tree
//! seeded generator (`util::rng`) — hundreds of random cases per
//! property, deterministic by default, overridable via ARROW_PROP_SEED.

use arrow_rvv::asm::assemble;
use arrow_rvv::isa::csr::Vtype;
use arrow_rvv::isa::reg::{VReg, XReg};
use arrow_rvv::isa::rvv::{AddrMode, MaskMode, VAluOp, VSrc2, VecInstr, VmemWidth};
use arrow_rvv::isa::rv32::{AluOp, BranchOp, LoadOp, MulDivOp, ScalarInstr, StoreOp};
use arrow_rvv::isa::{decode, disasm, encode, Instr};
use arrow_rvv::mem::{AxiBus, BurstKind, Dram, MemTiming};
use arrow_rvv::util::json;
use arrow_rvv::util::rng::Rng;
use arrow_rvv::vector::offset;
use arrow_rvv::vector::{ArrowConfig, ArrowUnit};

fn rng() -> Rng {
    let seed = std::env::var("ARROW_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xA770_2021);
    Rng::new(seed)
}

fn random_scalar_instr(r: &mut Rng) -> ScalarInstr {
    let rd = XReg(r.range_usize(0, 32) as u8);
    let rs1 = XReg(r.range_usize(0, 32) as u8);
    let rs2 = XReg(r.range_usize(0, 32) as u8);
    let imm12 = r.range_i64(-2048, 2048) as i32;
    match r.range_usize(0, 9) {
        0 => ScalarInstr::Lui { rd, imm: (r.range_i64(0, 1 << 20) as i32) << 12 },
        1 => ScalarInstr::Jal { rd, offset: (r.range_i64(-(1 << 19), 1 << 19) as i32) & !1 },
        2 => ScalarInstr::Jalr { rd, rs1, offset: imm12 },
        3 => {
            let op = *r.pick(&[
                BranchOp::Beq,
                BranchOp::Bne,
                BranchOp::Blt,
                BranchOp::Bge,
                BranchOp::Bltu,
                BranchOp::Bgeu,
            ]);
            ScalarInstr::Branch {
                op,
                rs1,
                rs2,
                offset: (r.range_i64(-4096, 4096) as i32) & !1,
            }
        }
        4 => {
            let op = *r.pick(&[
                LoadOp::Lb,
                LoadOp::Lh,
                LoadOp::Lw,
                LoadOp::Lbu,
                LoadOp::Lhu,
            ]);
            ScalarInstr::Load { op, rd, rs1, offset: imm12 }
        }
        5 => {
            let op = *r.pick(&[StoreOp::Sb, StoreOp::Sh, StoreOp::Sw]);
            ScalarInstr::Store { op, rs1, rs2, offset: imm12 }
        }
        6 => {
            let op = *r.pick(&[
                AluOp::Add,
                AluOp::Sll,
                AluOp::Slt,
                AluOp::Sltu,
                AluOp::Xor,
                AluOp::Srl,
                AluOp::Sra,
                AluOp::Or,
                AluOp::And,
            ]);
            let imm = if matches!(op, AluOp::Sll | AluOp::Srl | AluOp::Sra) {
                r.range_i64(0, 32) as i32
            } else {
                imm12
            };
            ScalarInstr::OpImm { op, rd, rs1, imm }
        }
        7 => {
            let op = *r.pick(&[
                AluOp::Add,
                AluOp::Sub,
                AluOp::Sll,
                AluOp::Slt,
                AluOp::Sltu,
                AluOp::Xor,
                AluOp::Srl,
                AluOp::Sra,
                AluOp::Or,
                AluOp::And,
            ]);
            ScalarInstr::Op { op, rd, rs1, rs2 }
        }
        _ => {
            let op = *r.pick(&[
                MulDivOp::Mul,
                MulDivOp::Mulh,
                MulDivOp::Mulhsu,
                MulDivOp::Mulhu,
                MulDivOp::Div,
                MulDivOp::Divu,
                MulDivOp::Rem,
                MulDivOp::Remu,
            ]);
            ScalarInstr::MulDiv { op, rd, rs1, rs2 }
        }
    }
}

fn random_vector_instr(r: &mut Rng) -> VecInstr {
    let vd = VReg(r.range_usize(0, 32) as u8);
    let vs2 = VReg(r.range_usize(0, 32) as u8);
    let rs1 = XReg(r.range_usize(0, 32) as u8);
    let mask = *r.pick(&[MaskMode::Unmasked, MaskMode::Masked]);
    let width = *r.pick(&[
        VmemWidth::E8,
        VmemWidth::E16,
        VmemWidth::E32,
        VmemWidth::E64,
    ]);
    match r.range_usize(0, 6) {
        0 => VecInstr::VsetVli {
            rd: XReg(r.range_usize(0, 32) as u8),
            rs1,
            vtypei: Vtype::new(
                *r.pick(&[8, 16, 32, 64]),
                *r.pick(&[1, 2, 4, 8]),
            )
            .encode(),
        },
        1 => {
            let mode = match r.range_usize(0, 3) {
                0 => AddrMode::UnitStride,
                1 => AddrMode::Strided { rs2: XReg(r.range_usize(0, 32) as u8) },
                _ => AddrMode::Indexed { vs2: VReg(r.range_usize(0, 32) as u8) },
            };
            VecInstr::Load { vd, rs1, width, mode, mask }
        }
        2 => {
            let mode = match r.range_usize(0, 3) {
                0 => AddrMode::UnitStride,
                1 => AddrMode::Strided { rs2: XReg(r.range_usize(0, 32) as u8) },
                _ => AddrMode::Indexed { vs2: VReg(r.range_usize(0, 32) as u8) },
            };
            VecInstr::Store { vs3: vd, rs1, width, mode, mask }
        }
        3 => VecInstr::MvXs { rd: rs1, vs2 },
        4 => VecInstr::MvSx { vd, rs1 },
        _ => {
            use VAluOp::*;
            let op = *r.pick(&[
                Add, Sub, Minu, Min, Maxu, Max, And, Or, Xor, Mseq, Msne,
                Msltu, Mslt, Msleu, Msle, Sll, Srl, Sra, Mul, Mulh, Mulhu,
                Divu, Div, Remu, Rem, RedSum, RedMax, RedMaxu, RedMin,
                RedMinu, RedAnd, RedOr, RedXor, Merge,
            ]);
            let src2 = if op.is_opm() {
                // OPM has no .vi form; reductions are .vs only.
                if op.is_reduction() || r.range_usize(0, 2) == 0 {
                    VSrc2::V(VReg(r.range_usize(0, 32) as u8))
                } else {
                    VSrc2::X(rs1)
                }
            } else {
                match r.range_usize(0, 3) {
                    0 => VSrc2::V(VReg(r.range_usize(0, 32) as u8)),
                    1 => VSrc2::X(rs1),
                    _ => VSrc2::I(r.range_i64(-16, 16) as i32),
                }
            };
            VecInstr::Alu { op, vd, vs2, src2, mask }
        }
    }
}

/// encode(decode(w)) == w and decode(encode(i)) == i over random
/// instructions — 2000 cases each way.
#[test]
fn prop_encode_decode_roundtrip() {
    let mut r = rng();
    for _ in 0..2000 {
        let i = if r.range_usize(0, 2) == 0 {
            Instr::Scalar(random_scalar_instr(&mut r))
        } else {
            Instr::Vector(random_vector_instr(&mut r))
        };
        let w = encode(i);
        let back = decode(w)
            .unwrap_or_else(|e| panic!("decode({w:#010x}) of {i:?}: {e}"));
        assert_eq!(back, i, "word {w:#010x}");
    }
}

/// decode never panics on arbitrary words.
#[test]
fn prop_decode_total() {
    let mut r = rng();
    for _ in 0..20_000 {
        let _ = decode(r.next_u32());
    }
}

/// disasm -> assemble round-trips for label-free instructions.
#[test]
fn prop_disasm_assemble_roundtrip() {
    let mut r = rng();
    let mut checked = 0;
    for _ in 0..1500 {
        let i = if r.range_usize(0, 2) == 0 {
            Instr::Scalar(random_scalar_instr(&mut r))
        } else {
            Instr::Vector(random_vector_instr(&mut r))
        };
        // Skip pc-relative / pseudo-ambiguous shapes.
        if matches!(
            i,
            Instr::Scalar(
                ScalarInstr::Branch { .. }
                    | ScalarInstr::Jal { .. }
                    | ScalarInstr::Lui { .. }
                    | ScalarInstr::Auipc { .. }
            )
        ) {
            continue;
        }
        let text = format!(".text\n{}\n", disasm(i));
        let p = assemble(&text)
            .unwrap_or_else(|e| panic!("`{}` failed: {e}", disasm(i)));
        assert_eq!(decode(p.text[0]).unwrap(), i, "text `{}`", disasm(i));
        checked += 1;
    }
    assert!(checked > 800);
}

/// Lane routing invariant (§3.3): an instruction's plan always books the
/// lane owning its destination register's bank.
#[test]
fn prop_lane_routing() {
    let mut r = rng();
    for lanes in [2usize, 4] {
        let config = ArrowConfig { lanes, ..Default::default() };
        let mut unit = ArrowUnit::new(config);
        let mut dram = Dram::new();
        // configure e32,m1 so any vd is legal
        unit.execute(
            VecInstr::VsetVli {
                rd: XReg(5),
                rs1: XReg(10),
                vtypei: Vtype::new(32, 1).encode(),
            },
            8,
            0,
            &mut dram,
        )
        .unwrap();
        for _ in 0..300 {
            let vd = VReg(r.range_usize(0, 32) as u8);
            let vs2 = VReg(r.range_usize(0, 32) as u8);
            let plan = unit
                .execute(
                    VecInstr::Alu {
                        op: VAluOp::Add,
                        vd,
                        vs2,
                        src2: VSrc2::V(vs2),
                        mask: MaskMode::Unmasked,
                    },
                    0,
                    0,
                    &mut dram,
                )
                .unwrap();
            assert_eq!(plan.lane, config.lane_of(vd.0));
        }
    }
}

/// Burst batching invariants: cost is monotone in beats; strided never
/// beats unit-stride; the bus serialises overlapping requests.
#[test]
fn prop_bus_batching() {
    let mut r = rng();
    let t = MemTiming::default();
    for _ in 0..500 {
        let a = r.range_i64(1, 512) as u64;
        let b = r.range_i64(1, 512) as u64;
        let (lo, hi) = (a.min(b), a.max(b));
        assert!(t.unit_burst(lo) <= t.unit_burst(hi));
        assert!(t.strided_burst(lo) <= t.strided_burst(hi));
        assert!(t.strided_burst(hi) >= t.unit_burst(hi));
    }
    for _ in 0..200 {
        let mut bus = AxiBus::new(t);
        let mut now = 0;
        let mut last_done = 0;
        for _ in 0..10 {
            now += r.range_i64(0, 5) as u64;
            let done = bus.schedule(
                now,
                *r.pick(&[BurstKind::Unit, BurstKind::Strided, BurstKind::Scalar]),
                r.range_i64(1, 64) as u64,
            );
            assert!(done >= last_done, "port must serialise");
            assert!(done > now);
            last_done = done;
        }
    }
}

/// vsetvli contract: vl = min(avl, VLEN*LMUL/SEW) over random configs.
#[test]
fn prop_vsetvli_vl() {
    let mut r = rng();
    let mut dram = Dram::new();
    for _ in 0..500 {
        let sew = *r.pick(&[8u32, 16, 32, 64]);
        let lmul = *r.pick(&[1u32, 2, 4, 8]);
        let avl = r.range_i64(0, 5000) as u32;
        let mut unit = ArrowUnit::new(ArrowConfig::default());
        let plan = unit
            .execute(
                VecInstr::VsetVli {
                    rd: XReg(5),
                    rs1: XReg(10),
                    vtypei: Vtype::new(sew, lmul).encode(),
                },
                avl,
                0,
                &mut dram,
            )
            .unwrap();
        let vlmax = 256 * lmul / sew;
        assert_eq!(plan.scalar_result, Some(avl.min(vlmax)));
        assert_eq!(unit.vl(), avl.min(vlmax));
    }
}

/// Register-state invariant: a masked element-wise op updates exactly the
/// enabled, sub-vl bytes (Fig 2) and nothing else.
#[test]
fn prop_write_enable_masks() {
    let mut r = rng();
    for _ in 0..800 {
        let sew_bytes = *r.pick(&[1usize, 2, 4, 8]);
        let group_bytes = 32 * *r.pick(&[1usize, 2, 4, 8]);
        let vl = r.range_usize(0, group_bytes / sew_bytes + 1);
        let bits: Vec<bool> =
            (0..group_bytes / sew_bytes).map(|_| r.range_usize(0, 2) == 1).collect();
        let we = offset::enable_for_mask(group_bytes, sew_bytes, vl, |e| bits[e]);
        let expected: usize = bits[..vl.min(bits.len())]
            .iter()
            .filter(|&&b| b)
            .count()
            * sew_bytes;
        assert_eq!(we.enabled(), expected);
        // every enabled byte belongs to an enabled element below vl
        for (i, &en) in we.bytes.iter().enumerate() {
            let elem = i / sew_bytes;
            assert_eq!(en, elem < vl && bits[elem], "byte {i}");
        }
    }
}

/// Simulated vadd equals the Rust oracle for random lengths and values —
/// end-to-end through assembler, host, dispatch, VRF, ALU, memory unit.
#[test]
fn prop_machine_vadd_random() {
    use arrow_rvv::bench::runner::{run_with_workload, Mode};
    use arrow_rvv::bench::suite::{BenchSize, Benchmark};
    let mut r = rng();
    for _ in 0..25 {
        let n = r.range_usize(1, 40) * 8;
        let size = BenchSize { n, k: 0, batch: 0 };
        let w = Benchmark::VAdd.workload(size, r.next_u64());
        let res = run_with_workload(
            Benchmark::VAdd,
            size,
            Mode::Vector,
            ArrowConfig::default(),
            &w,
        )
        .unwrap();
        assert!(res.verified, "n = {n}");
    }
}

/// JSON parser round-trips random documents built from the generator.
#[test]
fn prop_json_roundtrip() {
    fn random_json(r: &mut Rng, depth: usize) -> json::Json {
        use json::Json;
        match if depth == 0 { r.range_usize(0, 4) } else { r.range_usize(0, 6) } {
            0 => Json::Null,
            1 => Json::Bool(r.range_usize(0, 2) == 1),
            2 => Json::Num(r.range_i64(-1_000_000, 1_000_000) as f64),
            3 => Json::Str(
                (0..r.range_usize(0, 12))
                    .map(|_| *r.pick(&['a', 'Z', '"', '\\', '\n', '☃', ' ']))
                    .collect(),
            ),
            4 => Json::Arr(
                (0..r.range_usize(0, 5))
                    .map(|_| random_json(r, depth - 1))
                    .collect(),
            ),
            _ => Json::Obj(
                (0..r.range_usize(0, 5))
                    .map(|i| (format!("k{i}"), random_json(r, depth - 1)))
                    .collect(),
            ),
        }
    }
    let mut r = rng();
    for _ in 0..500 {
        let doc = random_json(&mut r, 3);
        let text = doc.to_string();
        let back = json::parse(&text)
            .unwrap_or_else(|e| panic!("`{text}`: {e}"));
        assert_eq!(back, doc, "`{text}`");
    }
}
