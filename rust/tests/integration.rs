//! Cross-layer integration tests: assembler -> machine -> benchmarks ->
//! XLA golden-model oracle -> reports.

use arrow_rvv::bench::analytic;
use arrow_rvv::bench::cnn::{run_cnn, CnnWorkload};
use arrow_rvv::bench::runner::{run_benchmark, run_with_workload, Mode};
use arrow_rvv::bench::suite::{BenchSize, Benchmark, BENCHMARKS};
use arrow_rvv::bench::{profiles, Profile};
use arrow_rvv::energy::EnergyModel;
use arrow_rvv::report;
#[cfg(feature = "pjrt")]
use arrow_rvv::runtime::Oracle;
use arrow_rvv::vector::ArrowConfig;

#[cfg(feature = "pjrt")]
fn oracle() -> Option<Oracle> {
    match Oracle::open_default() {
        Ok(o) => Some(o),
        Err(e) => {
            eprintln!("artifacts not built, skipping oracle checks: {e}");
            None
        }
    }
}

/// Every benchmark with a lowered artifact matches the XLA golden model
/// bit-exactly (the `arrow validate` path).
#[cfg(feature = "pjrt")]
#[test]
fn simulator_matches_xla_oracle() {
    let Some(mut oracle) = oracle() else { return };
    let config = ArrowConfig::default();
    let mut checked = 0;
    for b in BENCHMARKS {
        let size = b.size(&profiles::TEST);
        let Some(artifact) = b.oracle_artifact(size) else { continue };
        let w = b.workload(size, 42);
        let inputs: Vec<Vec<i32>> =
            w.inputs.iter().map(|(_, v)| v.clone()).collect();
        let golden: Vec<i32> = oracle
            .run_i32(&artifact, &inputs)
            .unwrap()
            .into_iter()
            .flatten()
            .collect();
        let sim =
            run_with_workload(b, size, Mode::Vector, config, &w).unwrap();
        assert_eq!(sim.output, golden, "{} vs `{artifact}`", b.name());
        checked += 1;
    }
    assert!(checked >= 8, "only {checked} artifact validations ran");
}

/// The end-to-end CNN agrees across all layers (the XLA layer only when
/// the `pjrt` oracle is compiled in).
#[test]
fn cnn_three_layer_agreement() {
    let w = CnnWorkload::generate(777);
    let expected = w.expected_logits();
    let (logits, _) = run_cnn(true, &w, ArrowConfig::default()).unwrap();
    assert_eq!(logits, expected);
    #[cfg(feature = "pjrt")]
    if let Some(mut o) = oracle() {
        let golden = o.run_i32("cnn", &w.oracle_inputs()).unwrap();
        assert_eq!(golden[0], expected);
    }
}

/// Scalar and vector variants compute identical results on every
/// benchmark (test profile).
#[test]
fn scalar_vector_equivalence() {
    let config = ArrowConfig::default();
    for b in BENCHMARKS {
        let size = b.size(&profiles::TEST);
        let w = b.workload(size, 99);
        let s = run_with_workload(b, size, Mode::Scalar, config, &w).unwrap();
        let v = run_with_workload(b, size, Mode::Vector, config, &w).unwrap();
        assert!(s.verified, "{} scalar", b.name());
        assert!(v.verified, "{} vector", b.name());
        assert_eq!(s.output, v.output, "{}", b.name());
    }
}

/// Table 3's qualitative claims (§5.2) hold on the small profile:
/// element-wise vector ops beat matrix max-pool, which beats conv.
#[test]
fn speedup_ordering_matches_paper() {
    let config = ArrowConfig::default();
    let speedup = |b: Benchmark, size: BenchSize| {
        let s = run_benchmark(b, size, Mode::Scalar, config, 5).unwrap();
        let v = run_benchmark(b, size, Mode::Vector, config, 5).unwrap();
        assert!(s.verified && v.verified);
        s.cycles as f64 / v.cycles as f64
    };
    let small = Profile::by_name("small").unwrap();
    let vadd = speedup(Benchmark::VAdd, Benchmark::VAdd.size(&small));
    let pool = speedup(Benchmark::MaxPool, Benchmark::MaxPool.size(&small));
    let conv = speedup(
        Benchmark::Conv2d,
        BenchSize { n: 64, k: 3, batch: 3 }, // scaled conv (image dim only)
    );
    assert!(vadd > pool, "vadd {vadd} !> maxpool {pool}");
    assert!(pool > conv, "maxpool {pool} !> conv {conv}");
    assert!(conv > 1.0, "conv should still win: {conv}");
}

/// Larger profiles amortize vector overheads: speedup is monotone in
/// data size (the paper's second §5.2 observation).
#[test]
fn speedup_grows_with_profile_size() {
    let config = ArrowConfig::default();
    let speedup = |n: usize| {
        let size = BenchSize { n, k: 0, batch: 0 };
        let s = run_benchmark(Benchmark::VAdd, size, Mode::Scalar, config, 5)
            .unwrap();
        let v = run_benchmark(Benchmark::VAdd, size, Mode::Vector, config, 5)
            .unwrap();
        s.cycles as f64 / v.cycles as f64
    };
    let (s64, s512, s4096) = (speedup(64), speedup(512), speedup(4096));
    assert!(s64 < s512, "{s64} !< {s512}");
    assert!(s512 <= s4096 * 1.05, "{s512} !<= {s4096}");
}

/// The analytic extrapolation agrees with full simulation at held-out
/// sizes for the cubic benchmark (matmul) — the DESIGN.md §6 guarantee.
#[test]
fn matmul_analytic_matches_simulation() {
    let config = ArrowConfig::default();
    // scalar: fit [16,32,48,64] -> check at 80
    let pred = analytic::extrapolate(
        Benchmark::MatMul,
        BenchSize { n: 80, k: 0, batch: 0 },
        Mode::Scalar,
        config,
    )
    .unwrap();
    let sim = analytic::cycles_auto(
        Benchmark::MatMul,
        BenchSize { n: 80, k: 0, batch: 0 },
        Mode::Scalar,
        config,
    )
    .unwrap()
    .0;
    let err = (pred as f64 - sim as f64).abs() / sim as f64;
    assert!(err < 0.01, "pred {pred} sim {sim}");
}

/// Vector matmul analytic fit holds at a strip-aligned held-out size.
#[test]
fn matmul_vector_analytic_matches_simulation() {
    let config = ArrowConfig::default();
    let size = BenchSize { n: 320, k: 0, batch: 0 };
    let pred =
        analytic::extrapolate(Benchmark::MatMul, size, Mode::Vector, config)
            .unwrap();
    let sim = run_benchmark(Benchmark::MatMul, size, Mode::Vector, config, 1)
        .unwrap()
        .cycles;
    let err = (pred as f64 - sim as f64).abs() / sim as f64;
    assert!(err < 0.01, "pred {pred} sim {sim} err {err}");
}

/// Full Table 3 + Table 4 generation on the test profile stays coherent:
/// energy ratios = (power ratio) / speedup.
#[test]
fn tables_internally_consistent() {
    let rows =
        report::table3(ArrowConfig::default(), &[profiles::TEST]).unwrap();
    assert_eq!(rows.len(), 9);
    let model = EnergyModel::default();
    for row in &rows {
        for (_, c) in &row.cells {
            let ratio = model.energy_ratio(c.scalar, c.vector);
            let expect = (model.system_power_w / model.scalar_power_w)
                / c.speedup();
            assert!(
                (ratio - expect).abs() < 1e-12,
                "{}: {ratio} vs {expect}",
                row.benchmark.name()
            );
        }
    }
    let t3 = report::render_table3(&rows);
    let t4 = report::render_table4(&rows, &model);
    assert!(t3.contains("Vector Addition"));
    assert!(t4.contains("2D Convolution"));
}

/// Design-space configurations all still compute correct results.
#[test]
fn correctness_across_design_space() {
    for lanes in [1usize, 2, 4] {
        for vlen in [128u32, 256, 512] {
            let config = ArrowConfig {
                lanes,
                vlen_bits: vlen,
                ..Default::default()
            };
            for b in [Benchmark::VDot, Benchmark::MatMul, Benchmark::MaxPool]
            {
                let size = BenchSize { n: 32, k: 0, batch: 0 };
                let r =
                    run_benchmark(b, size, Mode::Vector, config, 3).unwrap();
                assert!(
                    r.verified,
                    "{} wrong at lanes={lanes} vlen={vlen}",
                    b.name()
                );
            }
        }
    }
}

/// The energy model reproduces Table 4's structure for the paper's own
/// Table 3 cycle counts (sanity that the derivation is the paper's).
#[test]
fn paper_table4_derivation() {
    let m = EnergyModel::default();
    // Paper row: vector addition large, scalar 2.2e5 cycles -> 5.44e-4 J.
    let e = m.scalar_energy_j(220_000);
    assert!((e - 5.94e-4).abs() / 5.94e-4 < 0.1, "{e}");
    // Vector side: 2.8e3 cycles at 0.297 W -> 8.3e-6 J (paper 7.6e-6).
    let ev = m.vector_energy_j(2_800);
    assert!((ev - 7.6e-6).abs() / 7.6e-6 < 0.15, "{ev}");
}
