//! End-to-end SEW sweep: the Table-3 benchmarks only exercise e32, but
//! Arrow's SIMD ALU claim (Fig 3) is that one ELEN=64-bit word processes
//! 8/4/2/1 elements for SEW=8/16/32/64.  These tests run whole assembly
//! programs at every SEW through the assembler, host, dispatch, VRF,
//! write-enable and memory-unit paths, checking results bit-exactly and
//! the cycle model's word-pass arithmetic.

use arrow_rvv::asm::assemble;
use arrow_rvv::bench::profiles;
use arrow_rvv::bench::runner::Mode;
use arrow_rvv::bench::suite::Benchmark;
use arrow_rvv::bench::{point_key, EvalPoint, Evaluator, Provenance};
use arrow_rvv::scalar::ScalarTiming;
use arrow_rvv::system::Machine;
use arrow_rvv::util::rng::Rng;
use arrow_rvv::vector::ArrowConfig;

fn machine(src: &str) -> Machine {
    Machine::new(
        assemble(src).unwrap(),
        ArrowConfig::default(),
        ScalarTiming::default(),
    )
}

/// vadd at a given SEW over `n` elements; data written/read as raw bytes.
fn vadd_program(sew: u32, n: usize) -> String {
    let bytes = n * (sew as usize / 8);
    format!(
        r#"
        .data
        in_a: .space {bytes}
        in_b: .space {bytes}
        out:  .space {bytes}
        .text
            la a0, in_a
            la a1, in_b
            la a2, out
            li a3, {n}
        loop:
            vsetvli t0, a3, e{sew},m8
            vle{sew}.v v0, (a0)
            vle{sew}.v v8, (a1)
            vadd.vv v16, v0, v8
            vse{sew}.v v16, (a2)
            li t2, {sew_bytes}
            mul t1, t0, t2
            add a0, a0, t1
            add a1, a1, t1
            add a2, a2, t1
            sub a3, a3, t0
            bnez a3, loop
            halt
    "#,
        sew_bytes = sew / 8,
    )
}

fn write_elems(m: &mut Machine, label: &str, sew: u32, vals: &[i64]) {
    let addr = m.addr_of(label);
    let sb = (sew / 8) as usize;
    for (i, &v) in vals.iter().enumerate() {
        let bytes = v.to_le_bytes();
        m.dram.write_bytes(addr + (i * sb) as u32, &bytes[..sb]);
    }
}

fn read_elems(m: &Machine, label: &str, sew: u32, n: usize) -> Vec<i64> {
    let addr = m.addr_of(label);
    let sb = (sew / 8) as usize;
    (0..n)
        .map(|i| {
            let mut buf = [0u8; 8];
            m.dram.read_bytes(addr + (i * sb) as u32, &mut buf[..sb]);
            // sign-extend at SEW
            let raw = u64::from_le_bytes(buf);
            let shift = 64 - sew;
            ((raw << shift) as i64) >> shift
        })
        .collect()
}

#[test]
fn vadd_all_sews_bit_exact() {
    let mut rng = Rng::new(0x5E4);
    for sew in [8u32, 16, 32, 64] {
        let n = 100; // not strip-aligned: exercises vsetvli tails
        let lim = if sew == 64 { i64::MAX / 4 } else { 1i64 << (sew - 1) };
        let a: Vec<i64> = (0..n).map(|_| rng.range_i64(-lim, lim)).collect();
        let b: Vec<i64> = (0..n).map(|_| rng.range_i64(-lim, lim)).collect();
        let mut m = machine(&vadd_program(sew, n));
        write_elems(&mut m, "in_a", sew, &a);
        write_elems(&mut m, "in_b", sew, &b);
        m.run(1_000_000).unwrap();
        let got = read_elems(&m, "out", sew, n);
        let mask_shift = 64 - sew;
        let want: Vec<i64> = a
            .iter()
            .zip(&b)
            .map(|(&x, &y)| {
                ((x.wrapping_add(y) << mask_shift) as i64) >> mask_shift
            })
            .collect();
        assert_eq!(got, want, "SEW {sew}");
    }
}

#[test]
fn narrower_sew_means_fewer_word_passes() {
    // Same element count: e8 packs 8 elements per ELEN word, e64 packs 1
    // — the SIMD ALU claim.  Cycle counts must be monotone in SEW.
    let mut cycles = Vec::new();
    for sew in [8u32, 16, 32, 64] {
        let n = 256;
        let mut m = machine(&vadd_program(sew, n));
        let lim = if sew == 64 { i64::MAX / 4 } else { 1i64 << (sew - 1) };
        let mut rng = Rng::new(7);
        let a: Vec<i64> = (0..n).map(|_| rng.range_i64(-lim, lim)).collect();
        write_elems(&mut m, "in_a", sew, &a);
        write_elems(&mut m, "in_b", sew, &a);
        let s = m.run(1_000_000).unwrap();
        cycles.push((sew, s.cycles));
    }
    for w in cycles.windows(2) {
        assert!(
            w[0].1 < w[1].1,
            "e{} ({} cy) should beat e{} ({} cy)",
            w[0].0,
            w[0].1,
            w[1].0,
            w[1].1
        );
    }
}

#[test]
fn e8_relu_via_vmax() {
    let n = 64;
    let mut m = machine(
        r#"
        .data
        in_a: .space 64
        out:  .space 64
        .text
            la a0, in_a
            la a2, out
            li a3, 64
            vsetvli t0, a3, e8,m8
            vle8.v v0, (a0)
            vmax.vx v8, v0, zero
            vse8.v v8, (a2)
            halt
    "#,
    );
    let vals: Vec<i64> = (0..n).map(|i| i as i64 - 32).collect();
    write_elems(&mut m, "in_a", 8, &vals);
    m.run(10_000).unwrap();
    let got = read_elems(&m, "out", 8, n);
    let want: Vec<i64> = vals.iter().map(|&v| v.max(0)).collect();
    assert_eq!(got, want);
}

#[test]
fn e64_dot_product() {
    // SEW=64: one element per ELEN word, exercising the widest datapath.
    let n = 16;
    let mut m = machine(
        r#"
        .data
        in_a: .space 128
        in_b: .space 128
        out:  .space 8
        .text
            la a0, in_a
            la a1, in_b
            li a3, 16
            vsetvli t0, zero, e64,m8
            vmv.v.i v16, 0
        loop:
            vsetvli t0, a3, e64,m8
            vle64.v v0, (a0)
            vle64.v v8, (a1)
            vmul.vv v24, v0, v8
            vadd.vv v16, v16, v24
            slli t1, t0, 3
            add a0, a0, t1
            add a1, a1, t1
            sub a3, a3, t0
            bnez a3, loop
            vsetvli t0, zero, e64,m8
            vmv.s.x v0, zero
            vredsum.vs v8, v16, v0
            la a2, out
            vse64.v v8, (a2)
            halt
    "#,
    );
    // (the final vse64 at VLMAX spills the accumulator group past `out`
    // into unmapped scratch DRAM; only out[0] — the reduction — matters)
    let a: Vec<i64> = (0..n as i64).map(|i| i * 3 - 20).collect();
    let b: Vec<i64> = (0..n as i64).map(|i| 7 - i).collect();
    write_elems(&mut m, "in_a", 64, &a);
    write_elems(&mut m, "in_b", 64, &b);
    m.run(100_000).unwrap();
    let got = read_elems(&m, "out", 64, 1)[0];
    let want: i64 = a.iter().zip(&b).map(|(&x, &y)| x * y).sum();
    assert_eq!(got, want);
}

/// SEW-dependent timing ablations over the evaluation grid: design
/// points that differ only in ELEN or in a timing constant carry
/// distinct canonical keys, so they can never collide in the dedup
/// cache or the persistent store — each ablation simulates once and
/// replays its *own* numbers from then on.
#[test]
fn elen_and_timing_ablations_never_collide_in_the_store() {
    let dir = std::env::temp_dir()
        .join(format!("arrow-ablation-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let point = |config: ArrowConfig| EvalPoint {
        benchmark: Benchmark::VAdd,
        profile: profiles::TEST,
        mode: Mode::Vector,
        config,
    };
    let base = ArrowConfig::default();
    let narrow = ArrowConfig { elen_bits: 32, ..base };
    let slow_dispatch = {
        let mut c = base;
        c.timing.dispatch += 3;
        c
    };
    let slow_bus = {
        let mut c = base;
        c.mem_timing.burst_setup += 4;
        c
    };
    let ablations =
        [point(base), point(narrow), point(slow_dispatch), point(slow_bus)];

    // All four keys are distinct (ELEN and both timing models are
    // folded into the canonical key).
    let seed = 9;
    let keys: Vec<String> = ablations
        .iter()
        .map(|p| {
            point_key(p.benchmark, &p.profile, p.mode, &p.config, seed)
        })
        .collect();
    for (i, a) in keys.iter().enumerate() {
        assert!(a.contains("seed=9"), "{a}");
        for b in &keys[i + 1..] {
            assert_ne!(a, b);
        }
    }

    let evaluator = Evaluator::with_store_dir(&dir).unwrap();
    let first: Vec<_> = ablations
        .iter()
        .map(|p| evaluator.evaluate(p, seed, None).unwrap())
        .collect();
    for o in &first {
        assert_eq!(o.provenance, Provenance::Simulated);
        assert!(o.verified);
    }
    // The ablations genuinely change the cycle model...
    assert!(
        first[1].cycles > first[0].cycles,
        "ELEN 32 halves the elements per word pass: {} vs {}",
        first[1].cycles,
        first[0].cycles
    );
    assert!(
        first[2].cycles > first[0].cycles,
        "extra dispatch cycles must show up: {} vs {}",
        first[2].cycles,
        first[0].cycles
    );
    assert!(
        first[3].cycles > first[0].cycles,
        "slower bursts must show up: {} vs {}",
        first[3].cycles,
        first[0].cycles
    );
    // ...and every ablation stored its own record.
    assert_eq!(evaluator.store().unwrap().len(), ablations.len());

    // A fresh evaluator on the same dir replays each ablation's own
    // numbers — no cross-talk between grid variants.
    let replay = Evaluator::with_store_dir(&dir).unwrap();
    for (p, want) in ablations.iter().zip(&first) {
        let got = replay.evaluate(p, seed, None).unwrap();
        assert_eq!(got.provenance, Provenance::Cached);
        assert_eq!(got.origin, Provenance::Simulated);
        assert_eq!(got.cycles, want.cycles);
        assert_eq!(got.summary, want.summary);
    }
    // A different seed still misses: the key folds the workload in.
    let reseeded = replay.evaluate(&ablations[0], seed + 1, None).unwrap();
    assert_eq!(reseeded.provenance, Provenance::Simulated);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// The named timing presets behind the sweep grid's timing axis are
/// real ablations: each registered variant resolves by name, stamps a
/// distinct pair of cycle models, and therefore owns a distinct
/// canonical point key — so `--timing baseline,fast-dispatch,burst-mem`
/// can never collide in the dedup cache or the persistent store.
#[test]
fn named_timing_variants_are_distinct_design_points() {
    use arrow_rvv::bench::profiles::{TimingVariant, TIMING_VARIANTS};

    let seed = 3;
    let keys: Vec<String> = TIMING_VARIANTS
        .iter()
        .map(|v| {
            assert_eq!(
                TimingVariant::by_name(v.name).map(|x| x.name),
                Some(v.name)
            );
            let config = v.apply(ArrowConfig::default());
            assert_eq!(TimingVariant::name_for(&config), Some(v.name));
            point_key(
                Benchmark::VAdd,
                &profiles::TEST,
                Mode::Vector,
                &config,
                seed,
            )
        })
        .collect();
    for (i, a) in keys.iter().enumerate() {
        for b in &keys[i + 1..] {
            assert_ne!(a, b);
        }
    }
    // And each preset's simulation carries its own cycle count: the
    // faster-host and faster-memory variants both beat the baseline.
    let evaluator = Evaluator::new();
    let cycles: Vec<u64> = TIMING_VARIANTS
        .iter()
        .map(|v| {
            let point = EvalPoint {
                benchmark: Benchmark::VAdd,
                profile: profiles::TEST,
                mode: Mode::Vector,
                config: v.apply(ArrowConfig::default()),
            };
            let o = evaluator.evaluate(&point, seed, None).unwrap();
            assert!(o.verified, "{}", v.name);
            o.cycles
        })
        .collect();
    let (baseline, fast, burst) = (cycles[0], cycles[1], cycles[2]);
    assert!(fast < baseline, "fast-dispatch: {fast} vs {baseline}");
    assert!(burst < baseline, "burst-mem: {burst} vs {baseline}");
}

#[test]
fn mixed_sew_program_reconfigures() {
    // One program that switches SEW mid-stream: e32 add, then reinterpret
    // the same bytes as e8 and max against zero.
    let mut m = machine(
        r#"
        .data
        in_a: .space 32
        out:  .space 32
        .text
            la a0, in_a
            li a3, 8
            vsetvli t0, a3, e32,m1
            vle32.v v1, (a0)
            vadd.vv v2, v1, v1
            li a3, 32
            vsetvli t0, a3, e8,m1
            vmax.vx v3, v2, zero
            la a2, out
            vse8.v v3, (a2)
            halt
    "#,
    );
    let vals: Vec<i64> = vec![1, -1, 256, -256, 100, -100, 0, 3];
    write_elems(&mut m, "in_a", 32, &vals);
    m.run(10_000).unwrap();
    // expected: (v+v) as 4 bytes each, per-byte relu
    let mut want = Vec::new();
    for &v in &vals {
        for byte in ((v as i32).wrapping_add(v as i32)).to_le_bytes() {
            want.push((byte as i8).max(0) as i64);
        }
    }
    let got = read_elems(&m, "out", 8, 32);
    assert_eq!(got, want);
}
