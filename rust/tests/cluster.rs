//! Cluster integration: a sweep fanned across an in-process worker
//! fleet must merge into a report byte-identical (per point) to a local
//! run of the same spec, stay deterministic in point order, survive a
//! worker dying mid-sweep, share results across workers through one
//! cache dir, and refuse version-mismatched workers loudly.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpListener;
use std::path::PathBuf;
use std::thread;

use arrow_rvv::bench::cluster::{run_cluster, ClusterSpec};
use arrow_rvv::bench::profiles;
use arrow_rvv::bench::runner::Mode;
use arrow_rvv::bench::suite::Benchmark;
use arrow_rvv::bench::sweep::{report_json, run_sweep, SweepSpec};
use arrow_rvv::system::server;

/// Bind port 0, learn the address, and serve a real worker on a
/// background thread (leaked; the test process' exit reaps it).
fn spawn_worker(cache_dir: Option<PathBuf>) -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    thread::spawn(move || {
        let _ = server::serve_listener(listener, cache_dir.as_deref());
    });
    addr
}

/// A worker that answers the `shard` handshake correctly, then drops
/// every connection on its first real request — the wire-visible
/// behaviour of a worker killed mid-sweep.
fn spawn_flaky_worker() -> String {
    spawn_fake_worker(env!("CARGO_PKG_VERSION"))
}

/// Like [`spawn_flaky_worker`], but advertising an arbitrary version.
fn spawn_fake_worker(version: &str) -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let shard_response = format!(
        r#"{{"ok": true, "version": "{version}", "max_grid": 4096, "max_batch": 256}}"#
    );
    thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(stream) = stream else { break };
            let mut reader = BufReader::new(match stream.try_clone() {
                Ok(r) => r,
                Err(_) => continue,
            });
            let mut writer = stream;
            let mut line = String::new();
            if reader.read_line(&mut line).is_err() {
                continue;
            }
            if line.contains("shard") {
                let _ = writeln!(writer, "{shard_response}");
            }
            // Read (part of) the next request, then hang up on it.
            let mut next = String::new();
            let _ = reader.read_line(&mut next);
            drop(writer);
        }
    });
    addr
}

fn parity_spec() -> SweepSpec {
    SweepSpec {
        benchmarks: vec![Benchmark::VAdd, Benchmark::VDot],
        profiles: vec![profiles::TEST],
        modes: vec![Mode::Scalar, Mode::Vector],
        lanes: vec![1, 2],
        vlens: vec![128, 256],
        seed: 42,
        threads: 2,
        ..Default::default()
    }
}

fn points_json(report: &arrow_rvv::bench::SweepReport) -> String {
    report_json(report).get("points").unwrap().to_string()
}

/// A sweep fanned across two worker processes merges into the same
/// JSON report — same points, same order, same counters — as a local
/// `run_sweep` of the identical spec.
#[test]
fn cluster_sweep_is_identical_to_a_local_run() {
    let spec = parity_spec();
    let local = run_sweep(&spec);
    let workers = vec![spawn_worker(None), spawn_worker(None)];
    let mut cs = ClusterSpec::new(spec.clone(), workers);
    // Small shards + single-shard batches: the 16-point grid splits
    // into 4 shards so both workers genuinely share the fan-out.
    cs.shard_points = 4;
    cs.shards_per_batch = 1;
    let cluster = run_cluster(&cs).unwrap();

    assert_eq!(cluster.shards, 4);
    assert_eq!(cluster.local_shards, 0, "no fallback on a healthy fleet");
    assert!(cluster.workers.iter().all(|w| w.error.is_none()));
    assert_eq!(
        cluster.workers.iter().map(|w| w.shards).sum::<usize>(),
        cluster.shards
    );
    // The carve trace covers the whole grid, and the adaptive budget
    // never collapses on a healthy fleet of fast test-profile shards.
    assert_eq!(cluster.shard_sizes.iter().sum::<usize>(), spec.grid_len());
    assert!(cluster.shard_sizes.iter().all(|&n| n == 4));
    assert!(cluster.final_shard_cost > 4, "{}", cluster.final_shard_cost);
    // Pre-listed workers are static members with advertised caps, and
    // storeless workers report no ledger.
    for w in &cluster.workers {
        assert!(!w.joined, "{w:?}");
        assert!(w.caps.is_some(), "{w:?}");
        assert!(w.ledger.is_none(), "{w:?}");
    }

    // Byte-identical per-point JSON, deterministic order included.
    assert_eq!(points_json(&cluster.report), points_json(&local));
    let keys: Vec<&str> =
        cluster.report.points.iter().map(|p| p.key.as_str()).collect();
    let local_keys: Vec<&str> =
        local.points.iter().map(|p| p.key.as_str()).collect();
    assert_eq!(keys, local_keys);
    assert_eq!(cluster.report.unique_simulated, local.unique_simulated);
    assert_eq!(cluster.report.store_hits, local.store_hits);
    assert_eq!(cluster.report.analytic, local.analytic);
    assert_eq!(cluster.report.cache_hits, local.cache_hits);
    assert!(cluster.report.store_error.is_none());

    // Determinism across cluster runs too.
    let again = run_cluster(&cs).unwrap();
    assert_eq!(points_json(&again.report), points_json(&cluster.report));
}

/// The multi-precision axes ride the wire first-class: a 2-worker
/// cluster sweep over a grid spanning two ELENs and two timing
/// variants produces a distinct store key per point and merges
/// byte-identically to a local run — cost-sharded, deterministic.
#[test]
fn cluster_parity_over_elen_and_timing_axes() {
    let spec = SweepSpec {
        benchmarks: vec![Benchmark::VAdd, Benchmark::VDot],
        profiles: vec![profiles::TEST],
        modes: vec![Mode::Vector],
        lanes: vec![1, 2],
        vlens: vec![128, 256],
        elens: vec![32, 64],
        timing: vec![profiles::TIMING_BASELINE, profiles::TIMING_BURST_MEM],
        seed: 42,
        threads: 2,
        ..Default::default()
    };
    assert_eq!(spec.grid_len(), 32);
    let local = run_sweep(&spec);
    // Every grid point is a distinct design point: 32 distinct keys.
    let mut keys: Vec<&str> =
        local.points.iter().map(|p| p.key.as_str()).collect();
    keys.sort_unstable();
    keys.dedup();
    assert_eq!(keys.len(), spec.grid_len());

    let workers = vec![spawn_worker(None), spawn_worker(None)];
    let mut cs = ClusterSpec::new(spec, workers);
    cs.shard_points = 8;
    cs.shards_per_batch = 1;
    let cluster = run_cluster(&cs).unwrap();
    assert_eq!(cluster.local_shards, 0, "no fallback on a healthy fleet");
    assert_eq!(points_json(&cluster.report), points_json(&local));
    // The per-point JSON names the new axes.
    let j = report_json(&cluster.report);
    let points = j.get("points").unwrap().as_arr().unwrap();
    assert_eq!(points[0].get("elen").unwrap().as_u64(), Some(32));
    assert_eq!(points[0].get("timing").unwrap().as_str(), Some("baseline"));
    assert_eq!(points[1].get("timing").unwrap().as_str(), Some("burst-mem"));
    assert_eq!(points[2].get("elen").unwrap().as_u64(), Some(64));

    // Determinism across cluster runs, new axes included.
    let again = run_cluster(&cs).unwrap();
    assert_eq!(points_json(&again.report), points_json(&cluster.report));
}

/// Duplicate grid entries dedup to one evaluation with the duplicates
/// reported as cache hits — exactly as a local run counts them.
#[test]
fn cluster_counts_duplicate_entries_as_cache_hits() {
    let spec = SweepSpec {
        benchmarks: vec![Benchmark::VAdd],
        profiles: vec![profiles::TEST],
        modes: vec![Mode::Vector],
        lanes: vec![2, 2, 2],
        vlens: vec![256],
        seed: 3,
        threads: 1,
        ..Default::default()
    };
    let local = run_sweep(&spec);
    let mut cs =
        ClusterSpec::new(spec, vec![spawn_worker(None)]);
    cs.shard_points = 8;
    let cluster = run_cluster(&cs).unwrap();
    assert_eq!(cluster.report.unique_simulated, local.unique_simulated);
    assert_eq!(cluster.report.cache_hits, local.cache_hits);
    assert_eq!(cluster.report.cache_hits, 2);
    assert_eq!(points_json(&cluster.report), points_json(&local));
}

/// Killing a worker mid-sweep must not lose its shards: they retry on
/// the surviving worker (or locally) and the merged report still
/// matches a local run.
#[test]
fn worker_killed_mid_sweep_retries_on_survivors() {
    let spec = parity_spec();
    let local = run_sweep(&spec);
    // The flaky worker handshakes fine, then hangs up on its first
    // batch; listing it first makes it race for real work.
    let workers = vec![spawn_flaky_worker(), spawn_worker(None)];
    let mut cs = ClusterSpec::new(spec, workers);
    cs.shard_points = 4;
    cs.shards_per_batch = 1;
    let cluster = run_cluster(&cs).unwrap();

    let flaky = &cluster.workers[0];
    let healthy = &cluster.workers[1];
    assert!(
        flaky.error.is_some(),
        "the flaky worker must be reported lost: {flaky:?}"
    );
    assert_eq!(flaky.shards, 0);
    assert!(healthy.error.is_none());
    // Every shard was answered by the survivor or the local fallback —
    // never dropped.
    assert_eq!(
        healthy.shards + cluster.local_shards,
        cluster.shards
    );
    assert_eq!(points_json(&cluster.report), points_json(&local));
}

/// With every worker unreachable the whole grid falls back to local
/// evaluation — a cluster sweep always completes.
#[test]
fn all_workers_dead_falls_back_to_local_evaluation() {
    let spec = SweepSpec {
        benchmarks: vec![Benchmark::VMul],
        profiles: vec![profiles::TEST],
        modes: vec![Mode::Vector],
        lanes: vec![1, 2],
        vlens: vec![256],
        seed: 11,
        threads: 1,
        ..Default::default()
    };
    let local = run_sweep(&spec);
    // Grab a free port and release it: nothing listens there.
    let port = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().port()
    };
    let cs = ClusterSpec::new(spec, vec![format!("127.0.0.1:{port}")]);
    let cluster = run_cluster(&cs).unwrap();
    assert!(cluster.workers[0].error.is_some());
    assert_eq!(cluster.local_shards, cluster.shards);
    assert_eq!(points_json(&cluster.report), points_json(&local));
}

/// Workers sharing one `--cache-dir` persist every shard's results: a
/// second cluster sweep of the same spec — against the *same live
/// fleet* — answers entirely from the store, simulating nothing.
/// (Live workers fold in their peers' ledger appends before each
/// sweep request, so this holds even when round 2 lands a shard on
/// the worker that did not evaluate it in round 1.)
#[test]
fn shared_cache_dir_answers_second_sweep_from_store() {
    let dir = std::env::temp_dir().join(format!(
        "arrow-cluster-cache-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let spec = parity_spec();
    let workers = vec![
        spawn_worker(Some(dir.clone())),
        spawn_worker(Some(dir.clone())),
    ];

    let round = |spec: &SweepSpec| {
        let mut cs = ClusterSpec::new(spec.clone(), workers.clone());
        cs.shard_points = 4;
        cs.shards_per_batch = 1;
        run_cluster(&cs).unwrap()
    };

    let first = round(&spec);
    assert_eq!(first.local_shards, 0);
    assert!(first.report.unique_simulated > 0);
    assert_eq!(first.report.store_hits, 0);

    // The same live fleet answers round 2 without the simulator.
    let second = round(&spec);
    assert_eq!(second.local_shards, 0);
    assert_eq!(
        second.report.unique_simulated, 0,
        "second cluster sweep must simulate nothing"
    );
    assert_eq!(second.report.store_hits, first.report.unique_simulated);
    // Same ledgers, replayed: only the provenance tags differ.
    for (a, b) in first.report.points.iter().zip(&second.report.points) {
        assert_eq!(a.key, b.key);
        let (a, b) = (a.outcome.as_ref().unwrap(), b.outcome.as_ref().unwrap());
        assert_eq!(a.cycles, b.cycles, "cached replay diverged");
        assert_eq!(a.verified, b.verified);
        assert_eq!(a.summary, b.summary, "full ledger must replay");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

/// A version-mismatched worker is refused loudly — never silently
/// merged.
#[test]
fn version_mismatched_worker_is_refused() {
    let spec = SweepSpec {
        benchmarks: vec![Benchmark::VAdd],
        profiles: vec![profiles::TEST],
        modes: vec![Mode::Vector],
        lanes: vec![2],
        vlens: vec![256],
        seed: 1,
        threads: 1,
        ..Default::default()
    };
    let imposter = spawn_fake_worker("99.0.0");
    let cs = ClusterSpec::new(spec, vec![imposter]);
    let err = run_cluster(&cs).unwrap_err();
    assert!(err.contains("99.0.0"), "{err}");
    assert!(err.contains(env!("CARGO_PKG_VERSION")), "{err}");
    assert!(err.contains("refusing"), "{err}");
}
