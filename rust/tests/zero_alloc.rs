//! Decode-cache and allocation discipline for sealed sessions.
//!
//! `Session::machine()` seals the machine: the per-PC decode cache is
//! fully populated at session build, so the run loop must never
//! re-enter the decoder (`lazy_decodes() == 0`).  On top of that, the
//! steady-state execute loop (unmasked ALU ops, unit-stride memory,
//! scalar address arithmetic) holds the zero-allocation engine
//! contract: running the same strip-mined loop for 16x more iterations
//! must not grow the heap-allocation count, because every per-run
//! allocation (machine stamp-out, DDR3 paging of the touched pages,
//! the `RunSummary` ledger clone) is independent of the trip count.
//!
//! A counting global allocator turns that contract into a measured
//! number.  The whole file is a single test function on purpose: the
//! allocator counter is process-global, and a second test running on a
//! sibling harness thread would pollute the measured windows.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use arrow_rvv::asm::assemble;
use arrow_rvv::scalar::ScalarTiming;
use arrow_rvv::system::{Machine, Session};
use arrow_rvv::vector::ArrowConfig;

/// Counts every heap allocation so the zero-allocation claim is a
/// measured number, not an assertion.
struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn allocations() -> u64 {
    ALLOCATIONS.load(Ordering::Relaxed)
}

/// A strip-mined element-wise loop repeated `repeats` times over the
/// same 16-element array.  Every repeat touches the same DDR3
/// addresses, so the only thing that scales with `repeats` is executed
/// instructions — exactly what the allocation-invariance check needs.
fn strip_program(repeats: u32) -> String {
    format!(
        r#"
        .data
        xs: .word 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15, 16
        out: .space 64
        .text
            li a3, {repeats}
        outer:
            li a1, 16
            la a0, xs
            la a2, out
        strip:
            vsetvli t0, a1, e32,m1
            vle32.v v1, (a0)
            vadd.vv v2, v1, v1
            vse32.v v2, (a2)
            slli t1, t0, 2
            add a0, a0, t1
            add a2, a2, t1
            sub a1, a1, t0
            bnez a1, strip
            addi a3, a3, -1
            bnez a3, outer
            halt
    "#
    )
}

#[test]
fn sealed_sessions_run_decode_free_and_allocation_flat() {
    let config = ArrowConfig::default();
    let program = assemble(&strip_program(4)).unwrap();

    // Control: a lazily-decoding machine re-enters the decoder at least
    // once per distinct PC, so the leak detector below is known to be
    // able to fire.
    let mut lazy =
        Machine::new(program.clone(), config, ScalarTiming::default());
    lazy.run(1_000_000).unwrap();
    assert!(
        lazy.lazy_decodes() > 0,
        "lazy control machine never exercised the decoder; the \
         lazy_decodes counter is broken"
    );

    // Sealed machines: the session populated the whole decode cache up
    // front, so the run loop never falls back to the decoder.
    let short_session = Session::new(program, config).unwrap();
    let long_session =
        Session::new(assemble(&strip_program(64)).unwrap(), config).unwrap();
    let mut short_machine = short_session.machine();
    let mut long_machine = long_session.machine();

    let before = allocations();
    let short_summary = short_machine.run(1_000_000).unwrap();
    let short_allocs = allocations() - before;

    let before = allocations();
    let long_summary = long_machine.run(1_000_000).unwrap();
    let long_allocs = allocations() - before;

    assert_eq!(
        short_machine.lazy_decodes(),
        0,
        "sealed session machine re-entered the decoder"
    );
    assert_eq!(
        long_machine.lazy_decodes(),
        0,
        "sealed session machine re-entered the decoder"
    );

    // Make sure the two runs actually differ by enough work for a
    // per-instruction allocation to show up loudly.
    let short_instrs = short_summary.scalar_instructions
        + short_summary.vector_instructions;
    let long_instrs =
        long_summary.scalar_instructions + long_summary.vector_instructions;
    assert!(
        long_instrs > short_instrs + 400,
        "long run executed {long_instrs} instructions vs {short_instrs}; \
         not enough contrast to measure allocation invariance"
    );

    // The invariance itself: 16x the iterations, same allocation count
    // (a tiny slack absorbs one-off amortised container growth — a
    // per-instruction or per-iteration allocation would show up as
    // hundreds).
    let growth = long_allocs.saturating_sub(short_allocs);
    assert!(
        growth <= 8,
        "steady-state run loop allocates: short run made {short_allocs} \
         heap allocations, long run {long_allocs} (+{growth} across \
         {} extra instructions)",
        long_instrs - short_instrs
    );

    // The disabled flight recorder holds the same contract: span
    // begin/complete, instants and counter bumps on the hot path must
    // be free when no `--trace-out` sink is installed.  (Same test
    // function as above on purpose — the allocator counter is
    // process-global.)
    assert!(!arrow_rvv::obs::trace::enabled());
    let before = allocations();
    for i in 0..10_000u64 {
        let span = arrow_rvv::obs::trace::begin();
        arrow_rvv::obs::metrics::EVAL_SIMULATED.inc();
        arrow_rvv::obs::trace::complete(
            "eval",
            "eval",
            span,
            &[("tier", arrow_rvv::obs::trace::Arg::U64(i))],
        );
        arrow_rvv::obs::trace::instant(
            "cluster",
            "shard_carved",
            &[("shard", arrow_rvv::obs::trace::Arg::U64(i))],
        );
    }
    let disabled_allocs = allocations() - before;
    assert_eq!(
        disabled_allocs, 0,
        "disabled trace recorder allocated {disabled_allocs} times over \
         10k span/instant/counter rounds; the compiled-out path must be \
         allocation-free"
    );
}
