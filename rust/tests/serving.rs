//! The high-throughput serving path, end to end over real sockets:
//! pipelined requests on one connection answer concurrently yet
//! deliver byte-for-byte what a serial connection sees; a tagged ping
//! overtakes a slow request instead of head-of-line blocking behind
//! it; a full queue answers a structured `busy` rejection immediately;
//! a loopback `shutdown` drains in-flight work before the serve loop
//! returns; and the open-loop load generator drives a live server and
//! reports matching client/server counters.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::thread;
use std::time::{Duration, Instant};

use arrow_rvv::bench::loadgen::{self, LoadgenSpec};
use arrow_rvv::system::executor::ExecutorOptions;
use arrow_rvv::system::server;
use arrow_rvv::util::json::{self, Json};

/// Serve on port 0 with explicit executor sizing; the server thread is
/// leaked unless the test shuts it down (process exit reaps it).
fn spawn_server(exec: ExecutorOptions) -> (String, thread::JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let handle = thread::spawn(move || {
        let _ = server::serve_listener_opts(listener, None, None, exec);
    });
    (addr, handle)
}

fn connect(addr: &str) -> (TcpStream, BufReader<TcpStream>) {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).ok();
    let reader = BufReader::new(stream.try_clone().unwrap());
    (stream, reader)
}

fn read_response(reader: &mut BufReader<TcpStream>) -> String {
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(!line.is_empty(), "server closed the connection early");
    line
}

/// Ask a server to drain and exit, so tests that join the serve thread
/// (and CI runners) never leak a listener.
fn shutdown(addr: &str) {
    let (mut stream, mut reader) = connect(addr);
    writeln!(stream, r#"{{"cmd": "shutdown"}}"#).unwrap();
    let resp = json::parse(read_response(&mut reader).trim()).unwrap();
    assert_eq!(resp.get("draining"), Some(&Json::Bool(true)), "{resp}");
}

/// Untagged requests pipelined in one burst deliver exactly the bytes
/// a serial send-one-read-one connection gets, in the same order —
/// including error responses for unknown commands and malformed JSON,
/// which must hold their place in the reorder buffer like any other
/// response.
#[test]
fn pipelined_untagged_responses_match_sequential_byte_for_byte() {
    let (addr, handle) =
        spawn_server(ExecutorOptions { workers: 4, queue_depth: 32 });
    let requests = [
        r#"{"cmd": "ping"}"#,
        r#"{"cmd": "list"}"#,
        r#"{"cmd": "no_such_command"}"#,
        "this is not json",
        r#"{"cmd": "ping"}"#,
        r#"{"cmd": "list"}"#,
    ];

    // Serial baseline: one request on the wire at a time.
    let (mut stream, mut reader) = connect(&addr);
    let mut serial = Vec::new();
    for req in &requests {
        writeln!(stream, "{req}").unwrap();
        serial.push(read_response(&mut reader));
    }
    drop(stream);

    // Pipelined: the whole burst in one write, then read everything.
    let (mut stream, mut reader) = connect(&addr);
    let burst: String =
        requests.iter().map(|r| format!("{r}\n")).collect();
    stream.write_all(burst.as_bytes()).unwrap();
    let pipelined: Vec<String> =
        (0..requests.len()).map(|_| read_response(&mut reader)).collect();

    assert_eq!(serial, pipelined);
    drop(stream);
    shutdown(&addr);
    handle.join().unwrap();
}

/// A tagged ping submitted behind a slow request answers first: with
/// more than one pool worker there is no head-of-line blocking on a
/// connection, which is the whole point of pipelining.
#[test]
fn tagged_ping_overtakes_a_slow_sleep() {
    let (addr, handle) =
        spawn_server(ExecutorOptions { workers: 2, queue_depth: 8 });
    let (mut stream, mut reader) = connect(&addr);
    writeln!(stream, r#"{{"cmd": "sleep", "ms": 800, "id": 1}}"#).unwrap();
    writeln!(stream, r#"{{"cmd": "ping", "id": 2}}"#).unwrap();

    let started = Instant::now();
    let first = json::parse(read_response(&mut reader).trim()).unwrap();
    assert_eq!(
        first.get("id").and_then(Json::as_u64),
        Some(2),
        "ping should not wait behind the sleep: {first}"
    );
    assert!(
        started.elapsed() < Duration::from_millis(700),
        "ping was head-of-line blocked for {:?}",
        started.elapsed()
    );
    let second = json::parse(read_response(&mut reader).trim()).unwrap();
    assert_eq!(second.get("id").and_then(Json::as_u64), Some(1));
    assert_eq!(second.get("slept_ms").and_then(Json::as_u64), Some(800));
    drop(stream);
    shutdown(&addr);
    handle.join().unwrap();
}

/// When the queue is full, submission answers an immediate structured
/// `busy` rejection (with the request's id echoed) instead of blocking
/// the connection, and the server's `rejected` counter records it.
#[test]
fn queue_full_answers_structured_busy() {
    let (addr, handle) =
        spawn_server(ExecutorOptions { workers: 1, queue_depth: 1 });
    let (mut stream, mut reader) = connect(&addr);
    // One fills the worker, one fills the queue, two must be refused.
    // The pause lets the lone worker dequeue the first sleep, so the
    // reject set is deterministic: {2, 3}.
    writeln!(stream, r#"{{"cmd": "sleep", "ms": 600, "id": 0}}"#).unwrap();
    thread::sleep(Duration::from_millis(150));
    for id in 1..4 {
        writeln!(stream, r#"{{"cmd": "sleep", "ms": 600, "id": {id}}}"#)
            .unwrap();
    }
    let mut busy = Vec::new();
    let mut served = 0;
    for _ in 0..4 {
        let resp = json::parse(read_response(&mut reader).trim()).unwrap();
        if resp.get("busy").and_then(Json::as_bool) == Some(true) {
            assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{resp}");
            let error = resp.get("error").and_then(Json::as_str).unwrap();
            assert!(error.contains("queue full"), "{error}");
            busy.push(resp.get("id").and_then(Json::as_u64).unwrap());
        } else {
            assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
            served += 1;
        }
    }
    assert_eq!(busy, vec![2, 3], "the overflow requests get the busy");
    assert_eq!(served, 2);

    // `stats` is answered inline on the connection thread, so the
    // saturation counters stay observable even while the pool is busy.
    writeln!(stream, r#"{{"cmd": "stats"}}"#).unwrap();
    let stats = json::parse(read_response(&mut reader).trim()).unwrap();
    assert_eq!(stats.get("rejected").and_then(Json::as_u64), Some(2));
    drop(stream);
    shutdown(&addr);
    handle.join().unwrap();
}

/// A loopback `shutdown` acknowledges with `draining`, lets in-flight
/// work finish (the sleep's response still arrives), and the serve
/// loop returns — the graceful path `run_fleet` teardown and SIGTERM
/// both ride on.
#[test]
fn shutdown_drains_in_flight_work_then_serve_returns() {
    let (addr, handle) =
        spawn_server(ExecutorOptions { workers: 2, queue_depth: 8 });
    let (mut stream, mut reader) = connect(&addr);
    writeln!(stream, r#"{{"cmd": "sleep", "ms": 400, "id": 7}}"#).unwrap();
    writeln!(stream, r#"{{"cmd": "shutdown"}}"#).unwrap();

    let ack = json::parse(read_response(&mut reader).trim()).unwrap();
    assert_eq!(ack.get("draining"), Some(&Json::Bool(true)), "{ack}");
    // The in-flight sleep is drained, not dropped.
    let slept = json::parse(read_response(&mut reader).trim()).unwrap();
    assert_eq!(slept.get("id").and_then(Json::as_u64), Some(7));
    assert_eq!(slept.get("ok"), Some(&Json::Bool(true)), "{slept}");
    drop(stream);

    let deadline = Instant::now() + Duration::from_secs(10);
    while !handle.is_finished() {
        assert!(
            Instant::now() < deadline,
            "serve loop never returned after shutdown"
        );
        thread::sleep(Duration::from_millis(20));
    }
    handle.join().unwrap();
}

/// Count of OS threads in this process, from `/proc/self/status`.
/// `None` on platforms without procfs, where the fan-in test still
/// checks byte parity but skips the thread-count assertion.
fn thread_count() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .and_then(|v| v.trim().parse().ok())
}

/// 128 concurrent connections — a few hot, the rest idle — served by
/// the readiness-polled multiplexer: every hot connection sees exactly
/// the bytes a serial connection gets (error responses and malformed
/// lines included), and the process's OS-thread count does not grow
/// with the connection count, because the poller owns every socket.
#[test]
fn fan_in_many_connections_byte_parity_with_bounded_threads() {
    let (addr, handle) =
        spawn_server(ExecutorOptions { workers: 4, queue_depth: 64 });
    let requests = [
        r#"{"cmd": "ping"}"#,
        r#"{"cmd": "list"}"#,
        r#"{"cmd": "no_such_command"}"#,
        "not json at all",
        r#"{"cmd": "ping"}"#,
    ];

    // Serial baseline: one request on the wire at a time.
    let (mut stream, mut reader) = connect(&addr);
    let mut serial = Vec::new();
    for req in &requests {
        writeln!(stream, "{req}").unwrap();
        serial.push(read_response(&mut reader));
    }
    drop((stream, reader));

    let baseline_threads = thread_count();

    // 120 mostly-idle connections: one ping each, then they sit open
    // in the poll set for the rest of the test.
    let mut idle = Vec::new();
    for _ in 0..120 {
        let (mut stream, mut reader) = connect(&addr);
        writeln!(stream, r#"{{"cmd": "ping"}}"#).unwrap();
        let resp =
            json::parse(read_response(&mut reader).trim()).unwrap();
        assert_eq!(resp.get("pong"), Some(&Json::Bool(true)), "{resp}");
        idle.push((stream, reader));
    }

    // 8 hot connections, each pipelining the whole burst at once.
    let burst: String =
        requests.iter().map(|r| format!("{r}\n")).collect();
    let mut hot = Vec::new();
    for _ in 0..8 {
        let (mut stream, reader) = connect(&addr);
        stream.write_all(burst.as_bytes()).unwrap();
        hot.push((stream, reader));
    }

    // All 128 sockets are open and being served, yet the thread count
    // is what it was before any of them connected: a connection no
    // longer owns a thread.
    if let (Some(before), Some(now)) = (baseline_threads, thread_count())
    {
        assert!(
            now <= before + 4,
            "thread count grew with connections: {before} -> {now}"
        );
    }

    for (_, reader) in &mut hot {
        let got: Vec<String> = (0..requests.len())
            .map(|_| read_response(reader))
            .collect();
        assert_eq!(got, serial, "hot connection diverged from serial");
    }

    drop(hot);
    drop(idle);
    shutdown(&addr);
    handle.join().unwrap();
}

/// A connection that pipelines megabytes of responses without reading
/// them cannot stall the poller: past the write-queue cap further
/// requests answer a constant-size structured `busy` line, other
/// connections stay responsive throughout, and when the slow writer
/// finally reads, every id it sent has exactly one answer.
#[test]
fn slow_writer_is_shed_and_cannot_stall_other_connections() {
    let (addr, handle) =
        spawn_server(ExecutorOptions { workers: 2, queue_depth: 16 });
    const HOG_REQUESTS: u64 = 10_000;

    let (mut hog, hog_reader) = connect(&addr);
    // Thousands of metrics bodies are megabytes of response — far more
    // than the kernel socket buffers plus the server's write-queue cap
    // can absorb — so the overflow path must engage while this client
    // deliberately does not read.
    for id in 0..HOG_REQUESTS {
        writeln!(hog, r#"{{"cmd": "metrics", "id": {id}}}"#).unwrap();
    }

    // The poller is not stalled: a fresh connection's ping answers
    // promptly while the hog's responses sit queued unread.
    let started = Instant::now();
    let (mut probe, mut probe_reader) = connect(&addr);
    writeln!(probe, r#"{{"cmd": "ping"}}"#).unwrap();
    let resp =
        json::parse(read_response(&mut probe_reader).trim()).unwrap();
    assert_eq!(resp.get("pong"), Some(&Json::Bool(true)), "{resp}");
    assert!(
        started.elapsed() < Duration::from_secs(2),
        "poller stalled behind a slow writer for {:?}",
        started.elapsed()
    );
    drop((probe, probe_reader));

    // Now read everything back: every id answered exactly once, some
    // as full metrics bodies, the shed tail as structured `busy`.
    let mut reader = hog_reader;
    let mut seen = vec![false; HOG_REQUESTS as usize];
    let mut shed = 0u64;
    for _ in 0..HOG_REQUESTS {
        let resp =
            json::parse(read_response(&mut reader).trim()).unwrap();
        let id =
            resp.get("id").and_then(Json::as_u64).unwrap() as usize;
        assert!(!seen[id], "id {id} answered twice");
        seen[id] = true;
        if resp.get("busy").and_then(Json::as_bool) == Some(true) {
            assert_eq!(
                resp.get("ok"),
                Some(&Json::Bool(false)),
                "{resp}"
            );
            shed += 1;
        } else {
            assert!(resp.get("body").is_some(), "{resp}");
        }
    }
    assert!(seen.iter().all(|&s| s), "some requests never answered");
    assert!(shed > 0, "write-queue cap never engaged");
    drop(hog);
    shutdown(&addr);
    handle.join().unwrap();
}

/// The open-loop generator against a live server: every scheduled
/// request is sent, answered ok, measured client-side, and the report
/// embeds the server's own matching counters.
#[test]
fn loadgen_drives_a_live_server_and_reports_both_sides() {
    let (addr, handle) =
        spawn_server(ExecutorOptions { workers: 4, queue_depth: 64 });
    let out = std::env::temp_dir().join(format!(
        "BENCH_serve_latency_test_{}.json",
        std::process::id()
    ));
    let spec = LoadgenSpec {
        addr: addr.clone(),
        qps: 400.0,
        duration_s: 0.5,
        ramp_s: 0.0,
        connections: 2,
        out: Some(out.clone()),
        ..Default::default()
    };
    let report = loadgen::run(&spec).unwrap();

    let sent = report.get("sent").and_then(Json::as_u64).unwrap();
    assert_eq!(sent, 200, "400 qps x 0.5 s");
    assert_eq!(report.get("received").and_then(Json::as_u64), Some(sent));
    assert_eq!(report.get("ok").and_then(Json::as_u64), Some(sent));
    assert_eq!(report.get("busy").and_then(Json::as_u64), Some(0));
    assert_eq!(report.get("errors").and_then(Json::as_u64), Some(0));
    let latency = report.get("client_latency_us").unwrap();
    assert_eq!(latency.get("count").and_then(Json::as_u64), Some(sent));
    assert!(
        latency.get("p99_us").and_then(Json::as_u64).unwrap() > 0,
        "{latency}"
    );
    // The embedded server view counts at least our requests.
    let server_stats = report.get("server").unwrap();
    assert!(
        server_stats.get("served").and_then(Json::as_u64).unwrap() >= sent,
        "{server_stats}"
    );

    // The report on disk is the same JSON object.
    let disk = std::fs::read_to_string(&out).unwrap();
    assert_eq!(json::parse(disk.trim()).unwrap(), report);
    std::fs::remove_file(&out).ok();
    shutdown(&addr);
    handle.join().unwrap();
}
