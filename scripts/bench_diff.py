#!/usr/bin/env python3
"""Diff freshly generated BENCH_*.json reports against committed snapshots.

Usage:
    python3 scripts/bench_diff.py --fresh rust --snapshots bench/snapshots
    python3 scripts/bench_diff.py --fresh rust --snapshots bench/snapshots \
        --update

Snapshots are committed baselines of the benchmark reports the CI run
regenerates (`BENCH_serve_latency.json`, `BENCH_model_sweep.json`, ...).
They must ONLY ever be produced by an actual benchmark run in the CI /
driver environment — copy a fresh report with `--update` and commit the
result; never hand-edit or fabricate one.  Until a snapshot is
committed, the diff for that report is skipped with a notice and the
step still passes, so shipping the tooling never requires inventing
numbers.

Comparison policy (field classification by key name, applied
recursively; arrays align by index, or by their `key` field when the
elements carry one):

* latency-like fields (`*_us`, `*_ms`, `p50`/`p90`/`p99`, `*latency*`,
  `*wait*`): lower is better; FAIL if fresh > THRESHOLD x snapshot.
  CI-runner latencies are noisy, so the default threshold is a
  generous 3x.
* throughput-like fields (`*qps*`, `*throughput*`, `*per_s*`): higher
  is better; FAIL if fresh < snapshot / THRESHOLD.
* deterministic simulator fields (`cycles`, `*energy*`, `instret`,
  `grid`, `unique_simulated`): the simulator is seeded and cycle-exact,
  so FAIL on relative drift beyond 1%.
* everything else numeric: reported informationally, never failing —
  counts of sent/ok requests vary with wall-clock scheduling.
"""

import argparse
import json
import shutil
import sys
from pathlib import Path

THRESHOLD = 3.0  # generous ratio bound for noisy latency/throughput
EXACT_TOL = 0.01  # 1% relative drift allowed on deterministic fields

LATENCY_MARKERS = ("latency", "wait", "p50", "p90", "p99", "p999")
THROUGHPUT_MARKERS = ("qps", "throughput", "per_s")
EXACT_KEYS = ("cycles", "energy", "instret", "grid", "unique_simulated")


def classify(path):
    """Return 'latency' | 'throughput' | 'exact' | 'info' for a dotted
    field path; the last path component decides."""
    leaf = path.rsplit(".", 1)[-1].lower()
    if any(k in leaf for k in EXACT_KEYS):
        return "exact"
    if any(m in leaf for m in THROUGHPUT_MARKERS):
        return "throughput"
    if (
        leaf.endswith("_us")
        or leaf.endswith("_ms")
        or any(m in leaf for m in LATENCY_MARKERS)
    ):
        return "latency"
    return "info"


def walk(snapshot, fresh, path, out):
    """Collect (path, snapshot_value, fresh_value) for every numeric
    leaf present in both documents."""
    if isinstance(snapshot, dict) and isinstance(fresh, dict):
        for key in snapshot:
            if key in fresh:
                walk(snapshot[key], fresh[key], f"{path}.{key}", out)
    elif isinstance(snapshot, list) and isinstance(fresh, list):
        # Sweep reports list points that each carry a unique store
        # `key`; align on it so reordering is not drift.
        def by_key(items):
            keyed = {}
            for item in items:
                if not (isinstance(item, dict) and "key" in item):
                    return None
                keyed[item["key"]] = item
            return keyed

        snap_keyed, fresh_keyed = by_key(snapshot), by_key(fresh)
        if snap_keyed is not None and fresh_keyed is not None:
            for key, item in snap_keyed.items():
                if key in fresh_keyed:
                    walk(item, fresh_keyed[key], f"{path}[{key}]", out)
            return
        for i, (s, f) in enumerate(zip(snapshot, fresh)):
            walk(s, f, f"{path}[{i}]", out)
    elif isinstance(snapshot, (int, float)) and isinstance(
        fresh, (int, float)
    ) and not isinstance(snapshot, bool) and not isinstance(fresh, bool):
        out.append((path, float(snapshot), float(fresh)))


def diff_report(name, snapshot, fresh):
    """Compare one report; return a list of failure strings."""
    leaves = []
    walk(snapshot, fresh, name, leaves)
    failures = []
    checked = 0
    for path, snap, new in leaves:
        kind = classify(path)
        if kind == "info":
            continue
        checked += 1
        if kind == "latency" and new > snap * THRESHOLD and new - snap > 1:
            failures.append(
                f"{path}: {new:g} regressed past {THRESHOLD}x "
                f"snapshot {snap:g}"
            )
        elif kind == "throughput" and new < snap / THRESHOLD:
            failures.append(
                f"{path}: {new:g} fell below snapshot {snap:g} / "
                f"{THRESHOLD}"
            )
        elif kind == "exact":
            ref = max(abs(snap), 1e-12)
            if abs(new - snap) / ref > EXACT_TOL:
                failures.append(
                    f"{path}: deterministic field drifted "
                    f"{snap:g} -> {new:g}"
                )
    print(f"  {name}: {checked} gated fields, {len(failures)} regressions")
    return failures


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--fresh",
        type=Path,
        required=True,
        help="directory holding freshly generated BENCH_*.json",
    )
    ap.add_argument(
        "--snapshots",
        type=Path,
        required=True,
        help="directory of committed snapshot BENCH_*.json (may not exist)",
    )
    ap.add_argument(
        "--update",
        action="store_true",
        help="copy fresh reports over the snapshots (run in CI/driver "
        "env only — snapshots must come from a real run)",
    )
    args = ap.parse_args()

    fresh_files = sorted(args.fresh.glob("BENCH_*.json"))
    if not fresh_files:
        print(f"no fresh BENCH_*.json under {args.fresh}", file=sys.stderr)
        return 1

    if args.update:
        args.snapshots.mkdir(parents=True, exist_ok=True)
        for f in fresh_files:
            shutil.copy2(f, args.snapshots / f.name)
            print(f"snapshot updated: {args.snapshots / f.name}")
        return 0

    failures = []
    for f in fresh_files:
        snap_path = args.snapshots / f.name
        if not snap_path.exists():
            print(
                f"  {f.name}: no committed snapshot — skipped "
                "(commit one with --update from a real CI run)"
            )
            continue
        snapshot = json.loads(snap_path.read_text())
        fresh = json.loads(f.read_text())
        failures += diff_report(f.name, snapshot, fresh)

    if failures:
        print("\nbench regressions:", file=sys.stderr)
        for line in failures:
            print(f"  {line}", file=sys.stderr)
        return 1
    print("bench diff OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
