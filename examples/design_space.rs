//! Design-space exploration — Arrow's "configurable at design time" claim.
//!
//! Sweeps lane count and VLEN over representative benchmarks and reports
//! cycles, speedup over the scalar baseline, and the estimated FPGA
//! resource/power point (anchored to Table 2 at the paper's 2-lane /
//! VLEN=256 build).
//!
//! ```bash
//! cargo run --release --example design_space
//! ```

use arrow_rvv::bench::runner::{run_benchmark, Mode};
use arrow_rvv::bench::suite::Benchmark;
use arrow_rvv::bench::Profile;
use arrow_rvv::energy::resources;
use arrow_rvv::vector::ArrowConfig;

fn main() {
    let profile = Profile::by_name("small").unwrap();
    let benchmarks = [
        Benchmark::VAdd,
        Benchmark::VDot,
        Benchmark::MatMul,
        Benchmark::MaxPool,
    ];

    // Scalar baselines are design-point independent.
    let mut scalar = Vec::new();
    for b in benchmarks {
        let r = run_benchmark(
            b,
            b.size(&profile),
            Mode::Scalar,
            ArrowConfig::default(),
            7,
        )
        .unwrap();
        assert!(r.verified);
        scalar.push(r.cycles);
    }

    println!("design-space sweep, small profile (speedup over scalar)\n");
    print!("{:<22}", "configuration");
    for b in benchmarks {
        print!("{:>12}", b.name().trim_start_matches("vector_").trim_start_matches("matrix_"));
    }
    println!("{:>10}{:>9}{:>10}", "LUTs", "power", "fmax");

    for lanes in [1usize, 2, 4] {
        for vlen in [128u32, 256, 512] {
            let config = ArrowConfig {
                lanes,
                vlen_bits: vlen,
                ..Default::default()
            };
            if config.validate().is_err() {
                continue;
            }
            print!("{:<22}", format!("lanes={lanes} vlen={vlen}"));
            for (i, b) in benchmarks.iter().enumerate() {
                let r = run_benchmark(*b, b.size(&profile), Mode::Vector, config, 7)
                    .unwrap();
                assert!(r.verified, "{} misbehaves at lanes={lanes} vlen={vlen}", b.name());
                print!("{:>11.1}x", scalar[i] as f64 / r.cycles as f64);
            }
            let est = resources::estimate(&config);
            println!(
                "{:>10}{:>8.3}W{:>7.0}MHz",
                est.luts, est.power_w, est.fmax_mhz
            );
        }
    }

    println!(
        "\nthe paper's build is lanes=2 vlen=256 (Table 2: {} LUTs, {:.3} W, {:.0} MHz)",
        resources::ARROW_SYSTEM.luts,
        resources::ARROW_SYSTEM.power_w,
        resources::ARROW_SYSTEM.fmax_mhz
    );
    println!("design_space OK");
}
