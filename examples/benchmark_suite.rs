//! Regenerate the paper's full evaluation (Tables 2, 3, 4 + the §5.2
//! headline summaries) in one run.
//!
//! ```bash
//! cargo run --release --example benchmark_suite                 # small+medium
//! ARROW_PROFILES=small,medium,large \
//!   cargo run --release --example benchmark_suite               # everything
//! ```
//!
//! Large-profile rows use the analytic cycle-count extrapolation
//! (DESIGN.md §6) exactly as the harness's `cargo bench` targets do.

use arrow_rvv::bench::Profile;
use arrow_rvv::energy::EnergyModel;
use arrow_rvv::report;
use arrow_rvv::vector::ArrowConfig;

fn main() {
    let spec = std::env::var("ARROW_PROFILES")
        .unwrap_or_else(|_| "small,medium".to_string());
    let profiles: Vec<Profile> = spec
        .split(',')
        .map(|p| {
            Profile::by_name(p.trim())
                .unwrap_or_else(|| panic!("unknown profile `{p}`"))
        })
        .collect();

    let config = ArrowConfig::default();
    let model = EnergyModel::default();

    print!("{}", report::render_table2());
    println!();

    let rows = report::table3(config, &profiles).expect("table 3");
    print!("{}", report::render_table3(&rows));
    println!("\n§5.2 speedup summary:\n{}", report::speedup_summary(&rows));

    print!("{}", report::render_table4(&rows, &model));
    println!("\n§5.2 energy summary:\n{}", report::energy_summary(&rows, &model));

    println!("benchmark_suite OK ({} profiles)", profiles.len());
}
