//! End-to-end model inference — a built-in multi-kernel model run as
//! one first-class workload.
//!
//! `ModelSession` assembles every layer of the `tinycnn` built-in
//! (conv -> ReLU -> maxpool -> dense) through the shared program cache
//! once, then serves a batch of requests: each run executes the stages
//! back-to-back, handing every layer's *simulated* output tensor
//! forward as the next layer's activation, and the per-layer
//! sub-ledgers sum exactly to the end-to-end totals.
//!
//! ```bash
//! cargo run --release --example inference
//! ```

use arrow_rvv::bench::eval::SessionPool;
use arrow_rvv::bench::models::ModelId;
use arrow_rvv::bench::runner::{Mode, DEFAULT_BUDGET};
use arrow_rvv::bench::ProgramCache;
use arrow_rvv::energy::EnergyModel;
use arrow_rvv::system::ModelSession;
use arrow_rvv::vector::ArrowConfig;

fn main() {
    let config = ArrowConfig::default();
    let energy = EnergyModel::default();
    let model = ModelId::TinyCnn;
    let batch = 8u64;

    // Build once: all stage programs assemble through one shared cache,
    // so the whole batch pays the session-construction cost once.
    let programs = ProgramCache::new();
    let sessions = SessionPool::default();
    let vector = ModelSession::build(
        model, Mode::Vector, config, &programs, &sessions,
    )
    .expect("vector session");
    let scalar = ModelSession::build(
        model, Mode::Scalar, config, &programs, &sessions,
    )
    .expect("scalar session");

    println!(
        "serving {batch} inference requests on {} ({} layers)\n",
        model.qualified_name(),
        model.stages().len()
    );
    let (mut scalar_cycles, mut vector_cycles) = (0u64, 0u64);
    for req in 0..batch {
        let seed = 1000 + req;
        let rv = vector.run(seed, DEFAULT_BUDGET).expect("vector run");
        let rs = scalar.run(seed, DEFAULT_BUDGET).expect("scalar run");
        assert!(rv.verified, "request {req}: vectorized mismatch");
        assert!(rs.verified, "request {req}: scalar mismatch");
        assert_eq!(rv.output, rs.output, "modes must agree bit-exactly");
        assert_eq!(rv.output, model.workload(seed).expected);

        let class = rv
            .output
            .iter()
            .enumerate()
            .max_by_key(|(_, &v)| v)
            .map(|(i, _)| i)
            .unwrap();
        println!(
            "request {req}: class {class:>2}/{}   scalar {:>9} cy   \
             vector {:>8} cy   speedup {:>5.1}x",
            rv.output.len(),
            rs.summary.cycles,
            rv.summary.cycles,
            rs.summary.cycles as f64 / rv.summary.cycles as f64
        );
        scalar_cycles += rs.summary.cycles;
        vector_cycles += rv.summary.cycles;

        // Per-layer attribution for the first request: where the
        // model's cycles actually go, layer by layer.
        if req == 0 {
            let total: u64 = rv.stages.iter().map(|s| s.cycles).sum();
            assert_eq!(total, rv.summary.cycles, "sub-ledgers must sum");
            println!("  per-layer (vectorized):");
            for st in &rv.stages {
                println!(
                    "    {:<6} {:>8} cy ({:>4.1}%)  {:>6} vec instr  \
                     {:>8} B moved",
                    st.name,
                    st.cycles,
                    100.0 * st.cycles as f64 / total as f64,
                    st.vector_instructions,
                    st.mem_bytes
                );
            }
        }
    }

    let speedup = scalar_cycles as f64 / vector_cycles as f64;
    let es = energy.scalar_energy_j(scalar_cycles);
    let ev = energy.vector_energy_j(vector_cycles);
    println!("\nbatch summary (100 MHz system clock, Table 2 power model)");
    println!(
        "  scalar : {scalar_cycles} cycles, {:.3} ms, {es:.3e} J",
        1e3 * energy.time_s(scalar_cycles)
    );
    println!(
        "  vector : {vector_cycles} cycles, {:.3} ms, {ev:.3e} J",
        1e3 * energy.time_s(vector_cycles)
    );
    println!(
        "  speedup: {speedup:.1}x   energy ratio: {:.1}%",
        100.0 * ev / es
    );
    println!(
        "  throughput: {:.0} inferences/s (vectorized)",
        batch as f64 / energy.time_s(vector_cycles)
    );
    println!(
        "\ninference end-to-end OK — every layer verified against the \
         composed oracle"
    );
}
