//! End-to-end edge ML inference — the full three-layer stack in one run.
//!
//! 1. the tiny integer CNN (conv -> ReLU -> maxpool -> dense -> ReLU ->
//!    dense) defined in JAX/Pallas (python/compile/model.py) was
//!    AOT-lowered to `artifacts/cnn.hlo.txt` at build time;
//! 2. this driver executes that artifact via PJRT (the golden model),
//! 3. runs the same network as an RVV v0.9 program on the simulated
//!    MicroBlaze+Arrow system (scalar baseline AND vectorized),
//! 4. checks all three agree bit-exactly and reports the paper's headline
//!    metrics (cycles, speedup, energy) for a batch of requests.
//!
//! ```bash
//! make artifacts && cargo run --release --example inference
//! ```

use arrow_rvv::bench::cnn::{run_cnn, CnnWorkload, CLASSES};
use arrow_rvv::energy::EnergyModel;
use arrow_rvv::runtime::Oracle;
use arrow_rvv::vector::ArrowConfig;

fn main() {
    let config = ArrowConfig::default();
    let energy = EnergyModel::default();
    let batch = 8;

    let mut oracle = match Oracle::open_default() {
        Ok(o) => Some(o),
        Err(e) => {
            eprintln!(
                "WARNING: XLA oracle unavailable ({e}); validating against the Rust reference only"
            );
            None
        }
    };

    println!("serving a batch of {batch} inference requests on Arrow\n");
    let (mut scalar_cycles, mut vector_cycles) = (0u64, 0u64);
    for req in 0..batch {
        let w = CnnWorkload::generate(1000 + req);
        let expected = w.expected_logits();

        // L1/L2 golden model via XLA/PJRT.
        if let Some(o) = oracle.as_mut() {
            let golden = o
                .run_i32("cnn", &w.oracle_inputs())
                .expect("cnn artifact executes");
            assert_eq!(
                golden[0], expected,
                "XLA golden model disagrees with reference"
            );
        }

        // L3: the simulated system, both variants.
        let (logits_v, sv) = run_cnn(true, &w, config).expect("vector run");
        let (logits_s, ss) = run_cnn(false, &w, config).expect("scalar run");
        assert_eq!(logits_v, expected, "request {req}: vectorized mismatch");
        assert_eq!(logits_s, expected, "request {req}: scalar mismatch");

        let class = logits_v
            .iter()
            .enumerate()
            .max_by_key(|(_, &v)| v)
            .map(|(i, _)| i)
            .unwrap();
        println!(
            "request {req}: class {class:>2}/{CLASSES}   scalar {:>9} cy   vector {:>8} cy   speedup {:>5.1}x",
            ss.cycles,
            sv.cycles,
            ss.cycles as f64 / sv.cycles as f64
        );
        scalar_cycles += ss.cycles;
        vector_cycles += sv.cycles;
    }

    let speedup = scalar_cycles as f64 / vector_cycles as f64;
    let es = energy.scalar_energy_j(scalar_cycles);
    let ev = energy.vector_energy_j(vector_cycles);
    println!("\nbatch summary (100 MHz system clock, Table 2 power model)");
    println!(
        "  scalar : {scalar_cycles} cycles, {:.3} ms, {es:.3e} J",
        1e3 * energy.time_s(scalar_cycles)
    );
    println!(
        "  vector : {vector_cycles} cycles, {:.3} ms, {ev:.3e} J",
        1e3 * energy.time_s(vector_cycles)
    );
    println!(
        "  speedup: {speedup:.1}x   energy ratio: {:.1}%",
        100.0 * ev / es
    );
    println!(
        "  throughput: {:.0} inferences/s (vectorized)",
        batch as f64 / energy.time_s(vector_cycles)
    );
    println!("\ninference end-to-end OK — all three layers agree bit-exactly");
}
