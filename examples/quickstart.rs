//! Quickstart: assemble an RVV v0.9 program, run it on the simulated
//! MicroBlaze+Arrow system, and read back results and cycle counts.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use arrow_rvv::asm::assemble;
use arrow_rvv::isa::{decode, disasm};
use arrow_rvv::scalar::ScalarTiming;
use arrow_rvv::system::Machine;
use arrow_rvv::vector::ArrowConfig;

fn main() {
    // A strip-mined SAXPY-style kernel: z[i] = 3*x[i] + y[i], written the
    // way the paper's benchmarks are — vsetvli loop, LMUL=8 groups.
    let source = r#"
        .data
        xs:  .word 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15, 16
        ys:  .word 100, 100, 100, 100, 100, 100, 100, 100
             .word 200, 200, 200, 200, 200, 200, 200, 200
        zs:  .space 64
        .text
            la a0, xs
            la a1, ys
            la a2, zs
            li a3, 16            # element count
            li a4, 3             # scalar multiplier
        loop:
            vsetvli t0, a3, e32,m8
            vle32.v v0, (a0)
            vmul.vx v8, v0, a4   # 3 * x
            vle32.v v16, (a1)
            vadd.vv v24, v8, v16 # + y
            vse32.v v24, (a2)
            slli t1, t0, 2
            add a0, a0, t1
            add a1, a1, t1
            add a2, a2, t1
            sub a3, a3, t0
            bnez a3, loop
            halt
    "#;

    let program = assemble(source).expect("assembles");
    println!("assembled {} instructions:", program.len());
    for (i, &word) in program.text.iter().enumerate().take(6) {
        println!(
            "  {:#06x}: {:#010x}  {}",
            4 * i,
            word,
            disasm(decode(word).unwrap())
        );
    }
    println!("  ...\n");

    let mut machine = Machine::new(
        program,
        ArrowConfig::default(), // dual-lane, VLEN=256, ELEN=64 (the paper's build)
        ScalarTiming::default(),
    );
    let summary = machine.run(10_000).expect("runs to ecall");

    let zs = machine.addr_of("zs");
    let result = machine.dram.read_i32_slice(zs, 16);
    println!("z = 3*x + y        : {result:?}");
    assert_eq!(
        result,
        (1..=16)
            .map(|i| 3 * i + if i <= 8 { 100 } else { 200 })
            .collect::<Vec<i32>>()
    );

    println!("\nrun ledger");
    println!("  end-to-end cycles   : {}", summary.cycles);
    println!("  scalar instructions : {}", summary.scalar_instructions);
    println!("  vector instructions : {}", summary.vector_instructions);
    println!(
        "  lane busy cycles    : {:?}",
        &summary.lane_busy[..summary.lanes]
    );
    println!(
        "  AXI: {} transactions, {} beats, {} contention cycles",
        summary.bus.transactions,
        summary.bus.beats,
        summary.bus.contention_cycles
    );
    println!("\nquickstart OK");
}
